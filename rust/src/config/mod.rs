//! Configuration for the MINIMALIST system: circuit parameters
//! (the 22 nm FD-SOI-flavored electrical quantities the behavioral
//! simulator resolves), network architecture, and run/serving settings.
//!
//! Configs round-trip through the in-repo JSON module so experiments are
//! fully described by a single file (`--config path.json` on the CLI).

use anyhow::Result;

use crate::util::json::Json;

/// Boltzmann constant (J/K) — for kT/C sampling noise.
pub const K_BOLTZMANN: f64 = 1.380_649e-23;

/// Read an integer field from a JSON config object, falling back to
/// `dv` when absent (shared by every `from_json` in this module so the
/// parsing policy cannot diverge between configs).
fn json_usize(j: &Json, k: &str, dv: usize) -> usize {
    j.get(k).and_then(Json::as_f64).map(|x| x as usize).unwrap_or(dv)
}

/// Electrical + non-ideality parameters of the mixed-signal cores.
///
/// Defaults describe a plausible 22 nm FD-SOI operating point (paper §3.2):
/// 0.8 V core supply, MOM sampling capacitors of a few fF, ~1 % capacitor
/// mismatch, mV-scale comparator offset. The energy model is calibrated so
/// that the worst-case bound for 4 cores of 64×64 lands at the paper's
/// 169 pJ/step scale (§4.2; see `energy/`).
#[derive(Debug, Clone, PartialEq)]
pub struct CircuitConfig {
    /// Core supply voltage (V).
    pub v_dd: f64,
    /// Mid-rail reference V_0 = (V_00+V_11)/2 — the "zero" potential.
    pub v_0: f64,
    /// Weight-rail spacing (V): rail_w = V_0 + (w−1.5)·delta_w.
    pub delta_w: f64,
    /// Unit sampling capacitor (F). Each synapse has three of these.
    pub c_unit: f64,
    /// Relative capacitor mismatch σ (MOM caps match to ~1 %).
    pub sigma_c: f64,
    /// Temperature (K) for kT/C noise.
    pub temp_k: f64,
    /// Switch charge-injection capacitance (F): ΔQ = ±½·c_inj·V_dd on
    /// turn-off, sign from the deterministic clock feedthrough direction.
    pub c_inj: f64,
    /// Comparator input-referred offset σ (V), drawn once per instance.
    pub sigma_comp_offset: f64,
    /// Comparator input-referred noise σ (V), drawn per decision.
    pub sigma_comp_noise: f64,
    /// Transmission-gate gate capacitance (F) — energy accounting.
    pub c_gate: f64,
    /// SAR ADC: unit DAC capacitor (F); the 6-bit array totals 64 units.
    pub c_adc_unit: f64,
    /// Parasitic column-line capacitance (F), participates in shares.
    pub c_line: f64,
    /// Master seed for all stochastic effects.
    pub seed: u64,
    /// Disable every non-ideality (mismatch, noise, injection, parasitics)
    /// — the configuration parity tests run against the golden model.
    pub ideal: bool,
    /// Delta-sparsity threshold (EdgeDRNN-style accumulating delta):
    /// an input component only drives charge-share work when it moved
    /// more than `delta` since the last value it *fired* with. `0.0`
    /// (the default) disables the delta machinery entirely and runs the
    /// exact pre-delta code path — see [`delta_fires`] and ADR-005.
    pub delta: f64,
}

impl Default for CircuitConfig {
    fn default() -> Self {
        CircuitConfig {
            v_dd: 0.8,
            v_0: 0.4,
            delta_w: 0.1,
            // 9.7 fF MOM sampling cap: sized for ~1 % matching and
            // calibrated so the worst-case bound of 4×(64×64) cores lands
            // at the paper's 169 pJ/step (§4.2; see energy/).
            c_unit: 9.7e-15,
            sigma_c: 0.01,
            temp_k: 300.0,
            c_inj: 2e-17,
            sigma_comp_offset: 1.5e-3,
            sigma_comp_noise: 0.4e-3,
            c_gate: 2e-16,
            c_adc_unit: 2.5e-16,
            c_line: 2e-15,
            seed: 0xC0FFEE,
            ideal: false,
            delta: 0.0,
        }
    }
}

/// The accumulating-delta fire rule (EdgeDRNN, PAPERS.md): a component
/// fires when it moved more than `delta` away from the value it last
/// fired with — NOT from the previous step's value — so quantization
/// error stays bounded by `delta` instead of drifting across a run.
///
/// Written as a negated `<=` so a NaN `x_last` (the "never fired yet"
/// sentinel used by the satsim cores and the golden model) compares
/// false and therefore *fires*, which seeds the tracker on the first
/// step of every slot.
#[inline]
pub fn delta_fires(x: f64, x_last: f64, delta: f64) -> bool {
    !((x - x_last).abs() <= delta)
}

impl CircuitConfig {
    /// An idealized configuration: exact charge sharing, no noise — the
    /// simulator then reproduces the golden model bit-for-bit (up to f64
    /// rounding), which is how the satsim arithmetic is unit-tested.
    pub fn ideal() -> CircuitConfig {
        CircuitConfig { ideal: true, sigma_c: 0.0, c_inj: 0.0,
                        sigma_comp_offset: 0.0, sigma_comp_noise: 0.0,
                        c_line: 0.0, ..Default::default() }
    }

    /// Weight rail voltage for a 2-bit code: the four equidistant rails
    /// `V_00..V_11` around `V_0` (paper §3.2).
    pub fn rail_voltage(&self, code: u8) -> f64 {
        debug_assert!(code < 4);
        self.v_0 + (code as f64 - 1.5) * self.delta_w
    }

    /// kT/C noise σ (V) for a capacitance `c` (0 when ideal).
    pub fn ktc_sigma(&self, c: f64) -> f64 {
        if self.ideal {
            0.0
        } else {
            (K_BOLTZMANN * self.temp_k / c).sqrt()
        }
    }

    /// Serialize into the config JSON schema.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("v_dd", self.v_dd.into()),
            ("v_0", self.v_0.into()),
            ("delta_w", self.delta_w.into()),
            ("c_unit", self.c_unit.into()),
            ("sigma_c", self.sigma_c.into()),
            ("temp_k", self.temp_k.into()),
            ("c_inj", self.c_inj.into()),
            ("sigma_comp_offset", self.sigma_comp_offset.into()),
            ("sigma_comp_noise", self.sigma_comp_noise.into()),
            ("c_gate", self.c_gate.into()),
            ("c_adc_unit", self.c_adc_unit.into()),
            ("c_line", self.c_line.into()),
            ("seed", (self.seed as f64).into()),
            ("ideal", self.ideal.into()),
            ("delta", self.delta.into()),
        ])
    }

    /// Parse from the config JSON schema.
    pub fn from_json(j: &Json) -> Result<CircuitConfig> {
        let d = CircuitConfig::default();
        let f = |k: &str, dv: f64| j.get(k).and_then(Json::as_f64).unwrap_or(dv);
        Ok(CircuitConfig {
            v_dd: f("v_dd", d.v_dd),
            v_0: f("v_0", d.v_0),
            delta_w: f("delta_w", d.delta_w),
            c_unit: f("c_unit", d.c_unit),
            sigma_c: f("sigma_c", d.sigma_c),
            temp_k: f("temp_k", d.temp_k),
            c_inj: f("c_inj", d.c_inj),
            sigma_comp_offset: f("sigma_comp_offset", d.sigma_comp_offset),
            sigma_comp_noise: f("sigma_comp_noise", d.sigma_comp_noise),
            c_gate: f("c_gate", d.c_gate),
            c_adc_unit: f("c_adc_unit", d.c_adc_unit),
            c_line: f("c_line", d.c_line),
            seed: f("seed", d.seed as f64) as u64,
            ideal: j.get("ideal").and_then(Json::as_bool).unwrap_or(d.ideal),
            delta: f("delta", d.delta),
        })
    }
}

/// Network architecture (mirror of the python ModelConfig).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetworkConfig {
    /// Layer dims including input and readout, e.g. [1,64,64,64,64,10].
    pub dims: Vec<usize>,
}

impl NetworkConfig {
    /// The network configuration evaluated in the paper.
    pub fn paper() -> NetworkConfig {
        NetworkConfig { dims: vec![1, 64, 64, 64, 64, 10] }
    }

    /// Number of weight layers.
    pub fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }

    /// `(n_in, n_out)` of layer `l`.
    pub fn layer_shape(&self, l: usize) -> (usize, usize) {
        (self.dims[l], self.dims[l + 1])
    }
}

/// Core geometry: the physical array size a layer is mapped onto.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoreGeometry {
    /// Rows (input channels) per core.
    pub rows: usize,
    /// GRU columns per core (each column = one h/z synapse pair stack).
    pub cols: usize,
}

impl Default for CoreGeometry {
    fn default() -> Self {
        // The paper's energy estimate assumes 64×64 cores (§4.2).
        CoreGeometry { rows: 64, cols: 64 }
    }
}

impl CoreGeometry {
    /// Serialize into the config JSON schema.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("rows", self.rows.into()), ("cols", self.cols.into())])
    }

    /// Parse from the config JSON schema.
    pub fn from_json(j: &Json) -> Result<CoreGeometry> {
        let d = CoreGeometry::default();
        Ok(CoreGeometry {
            rows: json_usize(j, "rows", d.rows),
            cols: json_usize(j, "cols", d.cols),
        })
    }
}

/// Planner knobs for the layer→core mapping (see [`crate::mapping`]):
/// the target core geometry plus limits the planner must respect.
/// Round-trips through JSON like the other configs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MappingConfig {
    /// Physical array size of every core.
    pub geometry: CoreGeometry,
    /// Cap on the row replication of narrow layers (0 = replicate until
    /// the core rows are full, the default behavior).
    pub max_replication: usize,
    /// Hard budget on physical cores (0 = unlimited).
    pub max_cores: usize,
}

impl Default for MappingConfig {
    fn default() -> Self {
        MappingConfig {
            geometry: CoreGeometry::default(),
            max_replication: 0,
            max_cores: 0,
        }
    }
}

impl MappingConfig {
    /// Default planner knobs for a given geometry — the configuration
    /// the engine and the codesign slope fitter agree on implicitly.
    pub fn with_geometry(geometry: CoreGeometry) -> MappingConfig {
        MappingConfig { geometry, ..Default::default() }
    }

    /// Serialize into the config JSON schema.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("geometry", self.geometry.to_json()),
            ("max_replication", self.max_replication.into()),
            ("max_cores", self.max_cores.into()),
        ])
    }

    /// Parse from the config JSON schema.
    pub fn from_json(j: &Json) -> Result<MappingConfig> {
        let d = MappingConfig::default();
        Ok(MappingConfig {
            geometry: j
                .get("geometry")
                .map(CoreGeometry::from_json)
                .transpose()?
                .unwrap_or(d.geometry),
            max_replication: json_usize(j, "max_replication", d.max_replication),
            max_cores: json_usize(j, "max_cores", d.max_cores),
        })
    }
}

/// Default worker-thread count for the serving coordinator: one per
/// available CPU, with a floor of 1 when the parallelism is unknown.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Serving/coordination settings: how many backend workers the
/// coordinator shards requests across, and the dynamic-batching policy
/// they are fed with. Mirrors the `serve` CLI flags and round-trips
/// through JSON like the other configs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeConfig {
    /// Worker threads; each owns one backend instance constructed on
    /// that thread (PJRT handles are not `Send`).
    pub workers: usize,
    /// Flush a batch at this many queued requests…
    pub max_batch: usize,
    /// …or once the oldest queued request has waited this long (ms).
    pub max_wait_ms: u64,
    /// Streaming mode (`serve --streaming`): resident session slots per
    /// worker. A session leases one slot for its whole lifetime, so
    /// `workers × sessions` is the live-session capacity; opening one
    /// past it is rejected with `ServeError::Busy`.
    pub sessions: usize,
    /// Wire mode (`serve --http`): TCP port to listen on; 0 picks an
    /// ephemeral port (the CLI prints — and `--port-file` records —
    /// the bound address).
    pub http_port: u16,
    /// Largest request body the HTTP parser will buffer (bytes);
    /// oversized requests are refused with 413 before allocation.
    pub http_max_body_bytes: usize,
    /// Keep-alive read timeout (ms) — also the drain poll tick: an
    /// idle connection notices a shutdown within one tick, so this
    /// bounds the graceful-drain time too.
    pub http_keepalive_ms: u64,
    /// Intra-engine traversal lanes (`serve --engine-threads`): each
    /// worker's engine steps independent cores of one plan traversal on
    /// this many threads (ADR-007). Results are bit-identical at every
    /// value — purely a throughput knob. 1 = the serial path.
    pub engine_threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: default_workers(),
            max_batch: 16,
            max_wait_ms: 5,
            sessions: 8,
            http_port: 0,
            http_max_body_bytes: 1024 * 1024,
            http_keepalive_ms: 2000,
            engine_threads: 1,
        }
    }
}

impl ServeConfig {
    /// Serialize into the config JSON schema.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workers", self.workers.into()),
            ("max_batch", self.max_batch.into()),
            ("max_wait_ms", (self.max_wait_ms as f64).into()),
            ("sessions", self.sessions.into()),
            ("http_port", (self.http_port as usize).into()),
            ("http_max_body_bytes", self.http_max_body_bytes.into()),
            ("http_keepalive_ms", (self.http_keepalive_ms as f64).into()),
            ("engine_threads", self.engine_threads.into()),
        ])
    }

    /// Parse from the config JSON schema.
    pub fn from_json(j: &Json) -> Result<ServeConfig> {
        let d = ServeConfig::default();
        let workers = json_usize(j, "workers", d.workers).max(1);
        Ok(ServeConfig {
            workers,
            max_batch: json_usize(j, "max_batch", d.max_batch).max(1),
            max_wait_ms: j
                .get("max_wait_ms")
                .and_then(Json::as_f64)
                .map(|x| x as u64)
                .unwrap_or(d.max_wait_ms),
            sessions: json_usize(j, "sessions", d.sessions).max(1),
            http_port: json_usize(j, "http_port", d.http_port as usize)
                .min(u16::MAX as usize) as u16,
            http_max_body_bytes: json_usize(
                j,
                "http_max_body_bytes",
                d.http_max_body_bytes,
            )
            .max(1024),
            http_keepalive_ms: j
                .get("http_keepalive_ms")
                .and_then(Json::as_f64)
                .map(|x| (x as u64).max(10))
                .unwrap_or(d.http_keepalive_ms),
            engine_threads: json_usize(j, "engine_threads", d.engine_threads)
                .max(1),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rails_are_equidistant_and_centered() {
        let c = CircuitConfig::default();
        let v: Vec<f64> = (0..4).map(|w| c.rail_voltage(w)).collect();
        let d01 = v[1] - v[0];
        let d12 = v[2] - v[1];
        let d23 = v[3] - v[2];
        assert!((d01 - d12).abs() < 1e-12 && (d12 - d23).abs() < 1e-12);
        assert!(((v[0] + v[3]) / 2.0 - c.v_0).abs() < 1e-12);
        // all rails within the supply
        for x in v {
            assert!(x > 0.0 && x < c.v_dd);
        }
    }

    #[test]
    fn ktc_magnitude_sane() {
        let c = CircuitConfig::default();
        // kT/C for 4 fF at 300 K ≈ 1 mV — the well-known figure.
        let s = c.ktc_sigma(4e-15);
        assert!(s > 0.5e-3 && s < 2e-3, "kT/C sigma = {s}");
        assert_eq!(CircuitConfig::ideal().ktc_sigma(4e-15), 0.0);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = CircuitConfig::default();
        c.sigma_c = 0.025;
        c.seed = 42;
        c.delta = 0.05;
        let j = c.to_json();
        let back = CircuitConfig::from_json(&j).unwrap();
        assert_eq!(c, back);
        // older config files without the delta key load as delta=0
        let old = CircuitConfig::default().to_json();
        assert_eq!(CircuitConfig::from_json(&old).unwrap().delta, 0.0);
    }

    #[test]
    fn delta_fire_rule() {
        // moves within the threshold are quiescent, boundary inclusive
        assert!(!delta_fires(0.5, 0.5, 0.0));
        assert!(!delta_fires(0.52, 0.5, 0.02));
        assert!(!delta_fires(0.48, 0.5, 0.02));
        // anything beyond fires, in either direction
        assert!(delta_fires(0.53, 0.5, 0.02));
        assert!(delta_fires(-0.1, 0.1, 0.15));
        // the NaN "never fired" sentinel always fires
        assert!(delta_fires(0.0, f64::NAN, 1.0));
        // at delta=0 any nonzero move fires
        assert!(delta_fires(1.0, 1.0 + f64::EPSILON, 0.0));
    }

    #[test]
    fn network_shapes() {
        let n = NetworkConfig::paper();
        assert_eq!(n.n_layers(), 5);
        assert_eq!(n.layer_shape(0), (1, 64));
        assert_eq!(n.layer_shape(4), (64, 10));
    }

    #[test]
    fn mapping_json_roundtrip_and_defaults() {
        let m = MappingConfig {
            geometry: CoreGeometry { rows: 32, cols: 48 },
            max_replication: 8,
            max_cores: 12,
        };
        let back = MappingConfig::from_json(&m.to_json()).unwrap();
        assert_eq!(m, back);
        // missing keys fall back to defaults
        let empty = MappingConfig::from_json(&Json::obj(vec![])).unwrap();
        assert_eq!(empty, MappingConfig::default());
        assert_eq!(empty.geometry, CoreGeometry::default());
    }

    #[test]
    fn serve_defaults_sane() {
        let s = ServeConfig::default();
        assert!(s.workers >= 1);
        assert!(s.max_batch >= 1);
        assert!(s.sessions >= 1);
        assert_eq!(s.engine_threads, 1, "threading must be opt-in");
    }

    #[test]
    fn serve_json_roundtrip_and_clamping() {
        let s = ServeConfig {
            workers: 6,
            max_batch: 32,
            max_wait_ms: 9,
            sessions: 4,
            http_port: 8080,
            http_max_body_bytes: 64 * 1024,
            http_keepalive_ms: 500,
            engine_threads: 4,
        };
        let back = ServeConfig::from_json(&s.to_json()).unwrap();
        assert_eq!(s, back);
        // workers/max_batch/sessions are clamped to ≥ 1 on load, the
        // HTTP knobs to their own floors (1 KiB body, 10 ms tick)
        let j = Json::obj(vec![
            ("workers", 0usize.into()),
            ("max_batch", 0usize.into()),
            ("sessions", 0usize.into()),
            ("http_max_body_bytes", 3usize.into()),
            ("http_keepalive_ms", 1usize.into()),
            ("engine_threads", 0usize.into()),
        ]);
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.workers, 1);
        assert_eq!(c.max_batch, 1);
        assert_eq!(c.sessions, 1);
        assert_eq!(c.http_max_body_bytes, 1024);
        assert_eq!(c.http_keepalive_ms, 10);
        assert_eq!(c.engine_threads, 1);
        // missing HTTP keys fall back to defaults (older config files)
        let old = Json::obj(vec![("workers", 2usize.into())]);
        let c = ServeConfig::from_json(&old).unwrap();
        assert_eq!(c.http_port, ServeConfig::default().http_port);
        assert_eq!(
            c.http_max_body_bytes,
            ServeConfig::default().http_max_body_bytes
        );
    }
}
