"""Quantization primitives for the MINIMALIST hardware constraints.

The paper (§2) quantizes weights to 2 b, biases to 6 b, and the gating
variable z to 6 b; output activations are binarized with a Heaviside step.
Internal GRU states remain analog (fp in software).

All quantizers come in two flavours:
  * ``*_q``   — the pure forward quantizer (used at export / eval time and
                as the oracle for the hardware mapping),
  * ``*_ste`` — the straight-through-estimator version used inside
                quantization-aware training (identity gradient, clipped to
                the representable range).

Code conventions (shared with the rust side, see rust/src/quant/):
  * 2 b weight codes w ∈ {0,1,2,3} map to effective values
    ``(w - 1.5) * w_scale`` — two negative and two positive levels,
    mirroring the four equidistant voltages V_00..V_11 around
    V_0 = (V_00+V_11)/2 (paper §3.1.1). There is no exact zero weight.
  * 6 b bias codes b ∈ {-32..31} map to ``b * b_scale`` (b_scale is a
    per-layer power-of-two-free scalar chosen from the weight scale).
  * 6 b gate codes z ∈ {0..63} map to ``z / 63`` so that the swap count of
    the 64-capacitor bank (k = round(z*64/63) in hardware terms) covers the
    full [0, 1] mixing range.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Generic straight-through rounding
# ---------------------------------------------------------------------------


def ste_round(x: jax.Array) -> jax.Array:
    """Round-to-nearest with a straight-through (identity) gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def ste_clip(x: jax.Array, lo: float, hi: float) -> jax.Array:
    """Clip with straight-through gradient inside *and* outside the range.

    Using a hard clip in the backward pass kills gradients for saturated
    weights early in QAT; the straight-through variant keeps them alive,
    which is what lets the multi-stage schedule recover accuracy.
    """
    return x + jax.lax.stop_gradient(jnp.clip(x, lo, hi) - x)


# ---------------------------------------------------------------------------
# 2-bit weights
# ---------------------------------------------------------------------------

W2_LEVELS = jnp.array([-1.5, -0.5, 0.5, 1.5], dtype=jnp.float32)


def weight_scale(w: jax.Array) -> jax.Array:
    """Per-tensor scale for 2 b quantization.

    Chosen so the ±1.5·scale outer levels cover ~2σ of the weight
    distribution: scale = mean(|w|) / 0.75 (for a symmetric two-sided
    4-level grid the mean absolute reconstruction level is scale·(0.5+1.5)/2
    = scale so matching E|w| keeps the pre/post-quantization gain ≈ 1).
    """
    return jnp.maximum(jnp.mean(jnp.abs(w)), 1e-8)


def w2_codes(w: jax.Array, scale: jax.Array) -> jax.Array:
    """Quantize fp weights to integer codes {0,1,2,3}."""
    # level index for value v: round(v/scale + 1.5) clipped to [0, 3]
    idx = jnp.round(w / scale + 1.5)
    return jnp.clip(idx, 0, 3).astype(jnp.int32)


def w2_dequant(codes: jax.Array, scale: jax.Array) -> jax.Array:
    """Codes {0..3} → effective fp weights (w-1.5)·scale."""
    return (codes.astype(jnp.float32) - 1.5) * scale


def w2_q(w: jax.Array) -> jax.Array:
    """Pure-forward 2 b fake-quantization (per-tensor scale)."""
    s = weight_scale(w)
    return w2_dequant(w2_codes(w, s), s)


def w2_ste(w: jax.Array) -> jax.Array:
    """2 b fake-quant with straight-through gradients (QAT)."""
    s = jax.lax.stop_gradient(weight_scale(w))
    idx = ste_clip(ste_round(w / s + 1.5), 0.0, 3.0)
    return (idx - 1.5) * s


# ---------------------------------------------------------------------------
# 6-bit biases (signed, codes -32..31)
# ---------------------------------------------------------------------------


def bias_scale(b: jax.Array) -> jax.Array:
    """Per-tensor 6 b bias scale: the code range covers max|b|.

    Max-based (not σ-based): bias vectors are often near-constant (e.g.
    the slow-gate initialization b_z ≈ −4), where a σ-based scale would
    collapse to ~0 and quantize every bias to zero.
    """
    return jnp.maximum(jnp.max(jnp.abs(b)) / 31.0, 1e-8)


def b6_codes(b: jax.Array, scale: jax.Array) -> jax.Array:
    return jnp.clip(jnp.round(b / scale), -32, 31).astype(jnp.int32)


def b6_dequant(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * scale


def b6_q(b: jax.Array) -> jax.Array:
    s = bias_scale(b)
    return b6_dequant(b6_codes(b, s), s)


def b6_ste(b: jax.Array) -> jax.Array:
    s = jax.lax.stop_gradient(bias_scale(b))
    idx = ste_clip(ste_round(b / s), -32.0, 31.0)
    return idx * s


# ---------------------------------------------------------------------------
# Gate nonlinearities
# ---------------------------------------------------------------------------


def hard_sigmoid(u: jax.Array) -> jax.Array:
    """Piece-wise linear σ^z (paper Eq. 5): clip(u/6 + 1/2, 0, 1)."""
    return jnp.clip(u / 6.0 + 0.5, 0.0, 1.0)


def hard_sigmoid_ste(u: jax.Array) -> jax.Array:
    """σ^z with a straight-through clip: identical forward, but the
    gradient survives saturation. Without this, gates that start in the
    dead zones (u ≤ −3 after the slow-gate initialization) would never
    receive a learning signal in the hw phase."""
    return ste_clip(u / 6.0 + 0.5, 0.0, 1.0)


def z6_q(z: jax.Array) -> jax.Array:
    """Quantize a gate value z ∈ [0,1] to 6 b codes / 63 (pure forward)."""
    return jnp.round(jnp.clip(z, 0.0, 1.0) * 63.0) / 63.0


def z6_ste(z: jax.Array) -> jax.Array:
    """6 b gate quantization with straight-through gradient."""
    zc = ste_clip(z, 0.0, 1.0)
    return ste_round(zc * 63.0) / 63.0


@jax.custom_vjp
def heaviside_ste(h: jax.Array) -> jax.Array:
    """Binary output activation Θ(h) with a surrogate gradient.

    Forward: exact Heaviside (0/1). Backward: triangular surrogate
    max(0, 1-|h|) — the standard choice for binary-activation QAT; keeps
    the event-coded inter-layer communication trainable.
    """
    return (h > 0.0).astype(h.dtype)


def _heaviside_fwd(h):
    return heaviside_ste(h), h


def _heaviside_bwd(h, g):
    surrogate = jnp.clip(1.0 - jnp.abs(h), 0.0, 1.0)
    return (g * surrogate,)


heaviside_ste.defvjp(_heaviside_fwd, _heaviside_bwd)


def heaviside(h: jax.Array) -> jax.Array:
    """Pure-forward Heaviside Θ(h) (Eq. 4), no gradient definition."""
    return (h > 0.0).astype(h.dtype)
