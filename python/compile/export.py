"""MTF — the minimalist tensor file container.

A deliberately tiny binary format shared between the python build path and
the rust runtime (`rust/src/io/tensorfile.rs`), because the offline crate
set has no serde/npy. Little-endian throughout.

Layout:
    magic   4 bytes  b"MTF1"
    count   u32      number of tensors
    per tensor:
        name_len u16, name bytes (utf-8)
        dtype    u8   0=f32  1=i32  2=u8  3=i64  4=f64
        ndim     u8
        dims     u32 × ndim
        data     raw little-endian values (C order)

The rust side has both a reader and a writer; `python/tests/test_export.py`
and `rust/tests/mtf_roundtrip.rs` check the round trip from each end.
"""

from __future__ import annotations

import struct
from pathlib import Path

import numpy as np

MAGIC = b"MTF1"

_DTYPES: list[tuple[int, np.dtype]] = [
    (0, np.dtype("<f4")),
    (1, np.dtype("<i4")),
    (2, np.dtype("u1")),
    (3, np.dtype("<i8")),
    (4, np.dtype("<f8")),
]
_CODE_FOR = {dt: code for code, dt in _DTYPES}
_DTYPE_FOR = {code: dt for code, dt in _DTYPES}


def save_mtf(path: str | Path, tensors: dict[str, np.ndarray]) -> None:
    """Write tensors to an MTF container (insertion order preserved)."""
    out = bytearray()
    out += MAGIC
    out += struct.pack("<I", len(tensors))
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        dt = arr.dtype.newbyteorder("<")
        if dt not in _CODE_FOR:
            # normalize common dtypes (f64 stays f64; bool → u8; int → i32)
            if arr.dtype == np.bool_:
                arr, dt = arr.astype(np.uint8), np.dtype("u1")
            elif np.issubdtype(arr.dtype, np.integer):
                arr, dt = arr.astype("<i4"), np.dtype("<i4")
            elif np.issubdtype(arr.dtype, np.floating):
                arr, dt = arr.astype("<f4"), np.dtype("<f4")
            else:
                raise TypeError(f"unsupported dtype {arr.dtype} for {name!r}")
        nb = name.encode("utf-8")
        out += struct.pack("<H", len(nb)) + nb
        out += struct.pack("<BB", _CODE_FOR[dt], arr.ndim)
        out += struct.pack(f"<{arr.ndim}I", *arr.shape)
        out += arr.astype(dt, copy=False).tobytes(order="C")
    Path(path).write_bytes(bytes(out))


def load_mtf(path: str | Path) -> dict[str, np.ndarray]:
    """Read an MTF container back into {name: ndarray}."""
    buf = Path(path).read_bytes()
    if buf[:4] != MAGIC:
        raise ValueError(f"{path}: bad magic {buf[:4]!r}")
    (count,) = struct.unpack_from("<I", buf, 4)
    off = 8
    tensors: dict[str, np.ndarray] = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", buf, off)
        off += 2
        name = buf[off:off + nlen].decode("utf-8")
        off += nlen
        code, ndim = struct.unpack_from("<BB", buf, off)
        off += 2
        dims = struct.unpack_from(f"<{ndim}I", buf, off)
        off += 4 * ndim
        dt = _DTYPE_FOR[code]
        n = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(buf, dtype=dt, count=n, offset=off).reshape(dims)
        off += n * dt.itemsize
        tensors[name] = arr.copy()
    return tensors
