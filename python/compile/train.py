"""Multi-stage quantization-aware training of the MINIMALIST variants.

Reproduces the Fig 5 experiment: three models sharing the architecture
1-64-64-64-64-10 and the same trainable-parameter count, trained on
sequential digit data, evaluated as test accuracy across seeds.

The paper (§4.1) extends training to "a multistage process of 4 gradual
phases of quantization-aware training". The schedule here:

    fp32 target :  fp32
    quant target:  fp32 → qw (2-bit W) → qwb (+6-bit b) → quant (+Θ out)
    hw target   :  fp32 → qw → qwb → quant → hw (hard-σ, 6-bit z,
                   candidate activation removed, bias → comparator)

Each phase warm-starts from the previous phase's parameters (with the
re-parameterizations of model.adapt_params at the quant and hw hand-overs).

For the Fig 5 experiment the three targets share the initial fp32 trunk
(single-core CPU budget; DESIGN.md §2 documents the scale-down): the fp32
row continues training the baseline for the same *total* epoch count as
the hw path, so no row gets an epoch advantage.

optax is not available in this offline image, so the Adam optimizer is
implemented here directly (standard bias-corrected Adam, Kingma & Ba).

Usage (also driven by `make fig5`):
    python -m compile.train --variant hw --seed 0 --preset fast
    python -m compile.train --experiment fig5 --preset fast --seeds 2
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from .export import save_mtf

# ---------------------------------------------------------------------------
# Presets (scaled-down workloads; see DESIGN.md §2 for the substitution)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainPreset:
    name: str
    img_size: int          # T = img_size²
    n_train: int
    n_test: int
    batch: int
    epochs_per_phase: int
    lr: float
    dims: tuple[int, ...] = model_mod.DEFAULT_DIMS


PRESETS = {
    # smoke: seconds; plumbing-test only (far too little data to learn)
    "smoke": TrainPreset("smoke", img_size=8, n_train=240, n_test=120,
                         batch=40, epochs_per_phase=1, lr=1e-2),
    # fast: the default for EXPERIMENTS.md on this single-core testbed
    "fast": TrainPreset("fast", img_size=16, n_train=3000, n_test=1000,
                        batch=60, epochs_per_phase=4, lr=1e-2),
    # full: closer to the paper's budget (hours; use when time allows)
    "full": TrainPreset("full", img_size=16, n_train=6000, n_test=1500,
                        batch=60, epochs_per_phase=10, lr=1e-2),
}

# The synthetic generator provides unlimited i.i.d. samples, so each epoch
# draws a *fresh* training split (epoch index folded into the seed) — the
# data-efficiency equivalent of MNIST's 60 k images without the storage.
FRESH_DATA_PER_EPOCH = True

# Per-phase epoch multiplier: the fp32 trunk does the representation
# learning; the binarization (quant) and hardware (hw) phases need room to
# recover from their distribution shifts.
PHASE_EPOCH_WEIGHT = {"fp32": 4, "qw": 1, "qwb": 1, "quant": 2, "hw": 2}

PHASES_FOR_TARGET = {
    "fp32": ("fp32",),
    "quant": ("fp32", "qw", "qwb", "quant"),
    "hw": ("fp32", "qw", "qwb", "quant", "hw"),
}


# ---------------------------------------------------------------------------
# Adam (hand-rolled; optax unavailable offline)
# ---------------------------------------------------------------------------


def adam_init(params):
    return {
        "m": jax.tree_util.tree_map(jnp.zeros_like, params),
        "v": jax.tree_util.tree_map(jnp.zeros_like, params),
        "t": jnp.zeros((), jnp.int32),
    }


def adam_update(opt, grads, params, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = opt["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               opt["v"], grads)
    tf = t.astype(jnp.float32)
    corr = jnp.sqrt(1.0 - b2 ** tf) / (1.0 - b1 ** tf)
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * corr * m_ / (jnp.sqrt(v_) + eps),
        params, m, v)
    return {"m": m, "v": v, "t": t}, params


# ---------------------------------------------------------------------------
# Phase machinery
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def make_step_fn(cfg: model_mod.ModelConfig):
    """Jitted (trainable, opt, x, y, lr) → (trainable, opt, loss)."""

    def loss_fn(trainable, x_seq, labels):
        params, logit_scale = trainable
        logits = model_mod.forward_train(cfg, params, x_seq, logit_scale)
        return model_mod.cross_entropy(logits, labels)

    @jax.jit
    def step(trainable, opt, x_seq, labels, lr):
        loss, grads = jax.value_and_grad(loss_fn)(trainable, x_seq, labels)
        opt, trainable = adam_update(opt, grads, trainable, lr)
        return trainable, opt, loss

    return step


@functools.lru_cache(maxsize=None)
def make_eval_fn(cfg: model_mod.ModelConfig):
    @jax.jit
    def eval_logits(params, logit_scale, x_seq):
        return model_mod.forward_train(cfg, params, x_seq, logit_scale)

    return eval_logits


def cosine_lr(base: float, step: int, total: int, floor_frac: float = 0.1):
    """Cosine decay from base to base·floor_frac over `total` steps."""
    frac = min(step / max(total, 1), 1.0)
    return base * (floor_frac + (1 - floor_frac)
                   * 0.5 * (1 + np.cos(np.pi * frac)))


def evaluate(cfg, params, logit_scale, x, y, batch: int) -> float:
    """Test accuracy; x is [n, T, 1] (numpy), evaluated in batches."""
    eval_fn = make_eval_fn(cfg)
    correct = 0
    n = x.shape[0]
    for i in range(0, n, batch):
        xb = jnp.asarray(np.transpose(x[i:i + batch], (1, 0, 2)))
        logits = eval_fn(params, logit_scale, xb)
        correct += int((np.argmax(np.array(logits), -1)
                        == y[i:i + batch]).sum())
    return correct / n


def run_phase(phase: str, params, logit_scale, *, seed: int,
              preset: TrainPreset, dims, x_test, y_test, history: list,
              n_epochs: int, tag: str, verbose: bool = True):
    """Train one phase for n_epochs, mutating nothing; returns new state."""
    cfg = model_mod.ModelConfig(dims=dims, variant=phase)
    step_fn = make_step_fn(cfg)
    opt = adam_init((params, logit_scale))
    rng = np.random.default_rng(seed * 7919 + len(history) + 13)
    n_batches = preset.n_train // preset.batch
    total_steps = n_epochs * n_batches
    phase_tag = model_mod.VARIANTS.index(phase)
    gstep = 0
    acc = float("nan")
    for epoch in range(n_epochs):
        if FRESH_DATA_PER_EPOCH:
            xs, ys = data_mod.make_split(
                preset.n_train, size=preset.img_size,
                seed=seed * 131 + 1000 * phase_tag + epoch)
            x_train = data_mod.to_sequences(xs)
            y_train = ys
        order = rng.permutation(preset.n_train)
        losses = []
        for bi in range(n_batches):
            idx = order[bi * preset.batch:(bi + 1) * preset.batch]
            xb = jnp.asarray(np.transpose(x_train[idx], (1, 0, 2)))
            yb = jnp.asarray(y_train[idx])
            lr = jnp.float32(cosine_lr(preset.lr, gstep, total_steps))
            (params, logit_scale), opt, loss = step_fn(
                (params, logit_scale), opt, xb, yb, lr)
            losses.append(float(loss))
            gstep += 1
        acc = evaluate(cfg, params, logit_scale, x_test, y_test, preset.batch)
        history.append({"tag": tag, "phase": phase, "epoch": epoch,
                        "loss": float(np.mean(losses)), "test_acc": acc})
        if verbose:
            print(f"[{tag}] {phase} ep{epoch}: "
                  f"loss={np.mean(losses):.4f} acc={acc:.4f}", flush=True)
    return params, logit_scale, acc


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def train_variant(target: str, seed: int, preset: TrainPreset,
                  out_dir: Path, *, verbose: bool = True) -> dict:
    """Run the full multi-stage schedule for one (variant, seed)."""
    t_start = time.time()
    _, _, x_test, y_test = data_mod.dataset(
        1, preset.n_test, size=preset.img_size, seed=seed)
    dims = preset.dims

    params = model_mod.init_params(
        model_mod.ModelConfig(dims=dims, variant="fp32"), seed=seed)
    logit_scale = jnp.asarray(10.0, jnp.float32)

    history: list = []
    prev = None
    acc = float("nan")
    for phase in PHASES_FOR_TARGET[target]:
        if prev is not None:
            params, logit_scale = model_mod.adapt_params(
                params, logit_scale, prev, phase)
        n_epochs = preset.epochs_per_phase * PHASE_EPOCH_WEIGHT[phase]
        params, logit_scale, acc = run_phase(
            phase, params, logit_scale, seed=seed, preset=preset, dims=dims,
            x_test=x_test, y_test=y_test, history=history,
            n_epochs=n_epochs, tag=f"{target} s{seed}", verbose=verbose)
        prev = phase

    run = finish_run(target, seed, preset, out_dir, dims, params,
                     logit_scale, acc, history, t_start, verbose)
    return run


def finish_run(target, seed, preset, out_dir, dims, params, logit_scale,
               acc, history, t_start, verbose) -> dict:
    final_cfg = model_mod.ModelConfig(dims=dims, variant=target)
    run = {
        "variant": target, "seed": seed, "preset": preset.name,
        "dims": list(dims), "final_test_acc": acc,
        "wall_seconds": time.time() - t_start, "history": history,
    }
    run_dir = out_dir / f"{target}_s{seed}"
    run_dir.mkdir(parents=True, exist_ok=True)
    export_checkpoint(final_cfg, params, logit_scale, run_dir / "weights.mtf")
    (run_dir / "metrics.json").write_text(json.dumps(run, indent=1))
    if verbose:
        print(f"[{target} s{seed}] final acc={acc:.4f} "
              f"({run['wall_seconds']:.0f}s) → {run_dir}", flush=True)
    return run


def train_fig5_seed(seed: int, preset: TrainPreset, out_dir: Path,
                    *, verbose: bool = True) -> dict[str, float]:
    """One seed of the Fig 5 experiment with a shared fp32 trunk.

    Returns {variant: final_test_acc}. The fp32 row trains for the same
    total number of epochs as the hw path so the comparison is fair.
    """
    t0 = time.time()
    _, _, x_test, y_test = data_mod.dataset(
        1, preset.n_test, size=preset.img_size, seed=seed)
    dims = preset.dims
    E = preset.epochs_per_phase
    common = dict(seed=seed, preset=preset, dims=dims,
                  x_test=x_test, y_test=y_test, verbose=verbose)

    params = model_mod.init_params(
        model_mod.ModelConfig(dims=dims, variant="fp32"), seed=seed)
    ls = jnp.asarray(10.0, jnp.float32)

    accs: dict[str, float] = {}
    hist_trunk: list = []
    # shared trunk
    params, ls, _ = run_phase("fp32", params, ls, history=hist_trunk,
                              n_epochs=E * PHASE_EPOCH_WEIGHT["fp32"],
                              tag=f"fig5 s{seed} trunk", **common)

    # branch A: fp32 keeps training for parity with the hw path's total
    extra = E * (PHASE_EPOCH_WEIGHT["qw"] + PHASE_EPOCH_WEIGHT["qwb"]
                 + PHASE_EPOCH_WEIGHT["quant"] + PHASE_EPOCH_WEIGHT["hw"])
    hist_a = list(hist_trunk)
    pa, la, acc = run_phase("fp32", params, ls, history=hist_a,
                            n_epochs=extra, tag=f"fig5 s{seed} fp32", **common)
    accs["fp32"] = acc
    finish_run("fp32", seed, preset, out_dir, dims, pa, la, acc,
               hist_a, t0, verbose)

    # branch B: QAT chain
    hist_b = list(hist_trunk)
    pb, lb = params, ls
    prev = "fp32"
    for phase in ("qw", "qwb", "quant", "hw"):
        pb, lb = model_mod.adapt_params(pb, lb, prev, phase)
        pb, lb, acc = run_phase(phase, pb, lb, history=hist_b,
                                n_epochs=E * PHASE_EPOCH_WEIGHT[phase],
                                tag=f"fig5 s{seed} {phase}", **common)
        if phase in ("quant", "hw"):
            accs[phase] = acc
            finish_run(phase, seed, preset, out_dir, dims, pb, lb, acc,
                       hist_b, t0, verbose)
        prev = phase
    return accs


def load_checkpoint(path: Path):
    """Rebuild the raw parameter pytree from an exported checkpoint."""
    from .export import load_mtf

    t = load_mtf(path)
    dims = tuple(int(d) for d in t["meta.dims"])
    variant = bytes(t["meta.variant"]).rstrip(b"\0").decode()
    params = []
    for l in range(len(dims) - 1):
        params.append({
            "wh": jnp.asarray(t[f"l{l}.wh"]),
            "wz": jnp.asarray(t[f"l{l}.wz"]),
            "bh": jnp.asarray(t[f"l{l}.bh"]),
            "bz": jnp.asarray(t[f"l{l}.bz"]),
            "log_alpha": jnp.log(jnp.asarray(t[f"l{l}.alpha"][0])),
            "gamma": jnp.asarray(t[f"l{l}.gamma"][0]),
        })
    ls = jnp.asarray(t["meta.logit_scale"][0])
    return dims, variant, params, ls


def extend_run(resume_from: Path, target: str, seed: int, epochs: int,
               preset: TrainPreset, out_dir: Path, *, lr_scale: float = 0.5,
               verbose: bool = True) -> dict:
    """Continue training from a checkpoint, adapting variants if needed.

    Used to give the hw phase the longer recovery budget the sigmoid →
    hard-sigmoid hand-over needs without re-running the full schedule.
    """
    t0 = time.time()
    dims, from_variant, params, ls = load_checkpoint(resume_from)
    if from_variant != target:
        params, ls = model_mod.adapt_params(params, ls, from_variant, target)
    _, _, x_test, y_test = data_mod.dataset(
        1, preset.n_test, size=preset.img_size, seed=seed)
    scaled = dataclasses.replace(preset, lr=preset.lr * lr_scale)
    history: list = []
    params, ls, acc = run_phase(
        target, params, ls, seed=seed + 500, preset=scaled, dims=dims,
        x_test=x_test, y_test=y_test, history=history, n_epochs=epochs,
        tag=f"extend {target} s{seed}", verbose=verbose)
    return finish_run(target, seed, preset, out_dir, dims, params, ls,
                      acc, history, t0, verbose)


# ---------------------------------------------------------------------------
# Checkpoint export (MTF; consumed by rust/src/nn/weights.rs)
# ---------------------------------------------------------------------------


def export_checkpoint(cfg: model_mod.ModelConfig, params, logit_scale,
                      path: Path) -> None:
    """Serialize the trained network: raw fp params, and for quantized
    variants also the integer code planes + scales (what the SRAM images
    and the codesign spec consume on the rust side)."""
    tensors: dict[str, np.ndarray] = {
        "meta.dims": np.asarray(cfg.dims, np.int32),
        "meta.variant": np.frombuffer(
            cfg.variant.encode().ljust(8, b"\0"), np.uint8).copy(),
        "meta.logit_scale": np.asarray([float(logit_scale)], np.float32),
    }
    for li, p in enumerate(params):
        pre = f"l{li}."
        for k in ("wh", "wz", "bh", "bz"):
            tensors[pre + k] = np.asarray(p[k], np.float32)
        tensors[pre + "alpha"] = np.asarray(
            [float(jnp.exp(p["log_alpha"]))], np.float32)
        tensors[pre + "gamma"] = np.asarray([float(p["gamma"])], np.float32)
        if cfg.variant != "fp32":
            for k in ("wh", "wz"):
                w = np.asarray(p[k], np.float32)
                s = float(np.maximum(np.mean(np.abs(w)), 1e-8))
                codes = np.clip(np.round(w / s + 1.5), 0, 3).astype(np.int32)
                tensors[pre + k + "_codes"] = codes
                tensors[pre + k + "_scale"] = np.asarray([s], np.float32)
            for k in ("bh", "bz"):
                b = np.asarray(p[k], np.float32)
                s = float(np.maximum(np.abs(b).max() / 31.0, 1e-8))
                codes = np.clip(np.round(b / s), -32, 31).astype(np.int32)
                tensors[pre + k + "_codes"] = codes
                tensors[pre + k + "_scale"] = np.asarray([s], np.float32)
    save_mtf(path, tensors)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--variant", choices=model_mod.FIG5_VARIANTS)
    ap.add_argument("--experiment", choices=["fig5"],
                    help="run all Fig 5 variants × seeds (shared trunk)")
    ap.add_argument("--preset", default="fast", choices=sorted(PRESETS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--seeds", type=int, default=2,
                    help="number of seeds for --experiment fig5")
    ap.add_argument("--out", default="../runs")
    ap.add_argument("--resume-from", help="checkpoint to extend")
    ap.add_argument("--epochs", type=int, default=16,
                    help="epochs for --resume-from extension")
    ap.add_argument("--lr-scale", type=float, default=0.5)
    args = ap.parse_args(argv)

    preset = PRESETS[args.preset]
    out_dir = Path(args.out)
    if args.resume_from:
        if args.variant is None:
            ap.error("--resume-from requires --variant")
        extend_run(Path(args.resume_from), args.variant, args.seed,
                   args.epochs, preset, out_dir, lr_scale=args.lr_scale)
        return
    if args.experiment == "fig5":
        per_variant: dict[str, list[float]] = {
            v: [] for v in model_mod.FIG5_VARIANTS}
        for s in range(args.seeds):
            accs = train_fig5_seed(s, preset, out_dir)
            for v, a in accs.items():
                per_variant[v].append(a)
        results = {
            v: {"mean": float(np.mean(a)), "std": float(np.std(a)),
                "accs": a}
            for v, a in per_variant.items()
        }
        for v, r in results.items():
            print(f"== {v}: {r['mean']:.4f} ± {r['std']:.4f}")
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / "fig5_summary.json").write_text(
            json.dumps({"preset": preset.name, "seeds": args.seeds,
                        "results": results}, indent=1))
    else:
        if args.variant is None:
            ap.error("need --variant or --experiment")
        train_variant(args.variant, args.seed, preset, out_dir)


if __name__ == "__main__":
    main()
