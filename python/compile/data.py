"""synthMNIST — deterministic synthetic digit glyphs for sequential
classification.

The paper evaluates on sequential MNIST (28×28 images fed pixel-by-pixel,
input dimension 1, T=784). This environment has no network access, so MNIST
cannot be downloaded; per the substitution rule (DESIGN.md §2) we generate a
synthetic equivalent that exercises the identical code path: 10-way
classification of long 1-D pixel sequences.

Digits 0-9 are rendered from stroke skeletons (line segments in the unit
square) with a smooth distance-falloff brush, then perturbed per sample with
a random affine jitter (rotation, scale, translation, shear), stroke
thickness variation, and additive pixel noise. The generator is a pure
function of (seed, index) so train/test splits are reproducible and the
exported test set can be replayed bit-exactly on the rust side.

Default resolution is 16×16 → T=256 (scaled down from the paper's 784 to
fit CPU training in the session budget; DESIGN.md §2 documents this).
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Stroke skeletons. Coordinates in [0,1]^2, y growing downwards.
# Each digit: list of polylines; each polyline: list of (x, y) vertices.
# ---------------------------------------------------------------------------

DIGIT_STROKES: dict[int, list[list[tuple[float, float]]]] = {
    0: [[(0.50, 0.08), (0.78, 0.25), (0.78, 0.75), (0.50, 0.92),
         (0.22, 0.75), (0.22, 0.25), (0.50, 0.08)]],
    1: [[(0.35, 0.25), (0.55, 0.08), (0.55, 0.92)],
        [(0.30, 0.92), (0.75, 0.92)]],
    2: [[(0.25, 0.25), (0.40, 0.10), (0.65, 0.10), (0.78, 0.28),
         (0.70, 0.50), (0.25, 0.92), (0.78, 0.92)]],
    3: [[(0.25, 0.15), (0.60, 0.10), (0.75, 0.27), (0.55, 0.47),
         (0.75, 0.68), (0.60, 0.90), (0.25, 0.85)]],
    4: [[(0.65, 0.92), (0.65, 0.08), (0.22, 0.62), (0.80, 0.62)]],
    5: [[(0.75, 0.10), (0.30, 0.10), (0.28, 0.45), (0.60, 0.42),
         (0.78, 0.62), (0.70, 0.88), (0.25, 0.90)]],
    6: [[(0.70, 0.10), (0.35, 0.35), (0.25, 0.65), (0.40, 0.90),
         (0.70, 0.85), (0.75, 0.60), (0.45, 0.52), (0.27, 0.62)]],
    7: [[(0.22, 0.10), (0.78, 0.10), (0.45, 0.92)],
        [(0.35, 0.52), (0.68, 0.52)]],
    8: [[(0.50, 0.48), (0.70, 0.32), (0.62, 0.10), (0.38, 0.10),
         (0.30, 0.32), (0.50, 0.48), (0.72, 0.68), (0.60, 0.92),
         (0.40, 0.92), (0.28, 0.68), (0.50, 0.48)]],
    9: [[(0.73, 0.38), (0.55, 0.48), (0.30, 0.40), (0.25, 0.15),
         (0.55, 0.08), (0.73, 0.20), (0.73, 0.38), (0.65, 0.92)]],
}


def _segments(digit: int) -> np.ndarray:
    """Polylines → array of segments [n, 4] = (x1, y1, x2, y2)."""
    segs = []
    for line in DIGIT_STROKES[digit]:
        for (x1, y1), (x2, y2) in zip(line[:-1], line[1:]):
            segs.append((x1, y1, x2, y2))
    return np.asarray(segs, dtype=np.float32)


_SEGMENT_CACHE = {d: _segments(d) for d in range(10)}


def _render(segs: np.ndarray, size: int, thickness: float) -> np.ndarray:
    """Distance-field rendering of segments with a smooth brush."""
    # pixel-center grid in unit coords
    coords = (np.arange(size, dtype=np.float32) + 0.5) / size
    px, py = np.meshgrid(coords, coords)          # [size, size], y rows
    p = np.stack([px, py], axis=-1)[:, :, None, :]  # [s, s, 1, 2]

    a = segs[None, None, :, 0:2]                  # [1, 1, n, 2]
    b = segs[None, None, :, 2:4]
    ab = b - a
    denom = np.maximum((ab * ab).sum(-1), 1e-12)
    t = np.clip(((p - a) * ab).sum(-1) / denom, 0.0, 1.0)
    proj = a + t[..., None] * ab
    d = np.sqrt(((p - proj) ** 2).sum(-1))        # [s, s, n]
    dmin = d.min(axis=-1)
    # smooth brush: 1 inside thickness, soft decay outside
    img = np.clip(1.5 - dmin / thickness, 0.0, 1.0)
    return img.astype(np.float32)


def _affine_jitter(segs: np.ndarray, rng: np.random.Generator,
                   rot: float, scale_lo: float, scale_hi: float,
                   shift: float, shear: float) -> np.ndarray:
    """Random affine transform of segment endpoints about the glyph center."""
    th = rng.uniform(-rot, rot)
    sx = rng.uniform(scale_lo, scale_hi)
    sy = rng.uniform(scale_lo, scale_hi)
    sh = rng.uniform(-shear, shear)
    tx = rng.uniform(-shift, shift)
    ty = rng.uniform(-shift, shift)
    c, s = np.cos(th), np.sin(th)
    m = np.array([[c * sx, (-s + sh) * sy],
                  [s * sx, c * sy]], dtype=np.float32)
    pts = segs.reshape(-1, 2) - 0.5
    pts = pts @ m.T + np.array([0.5 + tx, 0.5 + ty], dtype=np.float32)
    return pts.reshape(-1, 4)


def make_glyph(digit: int, *, size: int = 16, seed: int = 0,
               index: int = 0, noise: float = 0.05) -> np.ndarray:
    """Render one jittered digit glyph. Pure function of (digit, seed, index)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, digit, index]))
    segs = _affine_jitter(_SEGMENT_CACHE[digit], rng,
                          rot=0.25, scale_lo=0.82, scale_hi=1.12,
                          shift=0.06, shear=0.15)
    thickness = rng.uniform(0.045, 0.075)
    img = _render(segs, size, thickness)
    img = img + rng.normal(0.0, noise, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def make_split(n: int, *, size: int = 16, seed: int = 0,
               noise: float = 0.05) -> tuple[np.ndarray, np.ndarray]:
    """Generate n samples: images [n, size, size] f32, labels [n] i32.

    Labels cycle through 0..9 then are shuffled deterministically, so every
    split is class-balanced.
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xD1617]))
    labels = np.arange(n, dtype=np.int32) % 10
    rng.shuffle(labels)
    imgs = np.stack([
        make_glyph(int(d), size=size, seed=seed, index=i, noise=noise)
        for i, d in enumerate(labels)
    ])
    return imgs, labels


def to_sequences(imgs: np.ndarray) -> np.ndarray:
    """Images [n, s, s] → pixel sequences [n, T=s*s, 1] (row-major scan).

    This is the 'sequential' encoding of the paper: one analog pixel value
    per time step, input dimension 1.
    """
    n = imgs.shape[0]
    return imgs.reshape(n, -1, 1).astype(np.float32)


def dataset(n_train: int, n_test: int, *, size: int = 16, seed: int = 0):
    """Full dataset as (x_train, y_train, x_test, y_test), sequence-encoded."""
    xtr, ytr = make_split(n_train, size=size, seed=seed)
    xte, yte = make_split(n_test, size=size, seed=seed + 1_000_003)
    return to_sequences(xtr), ytr, to_sequences(xte), yte


def main(argv=None) -> None:
    """CLI: export the canonical test split as an MTF artifact for the
    rust side (bit-exact parity evaluation; DESIGN.md §7)."""
    import argparse

    from .export import save_mtf

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--export", default="../artifacts/synthmnist_test.mtf")
    ap.add_argument("--n", type=int, default=500)
    ap.add_argument("--size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    imgs, labels = make_split(args.n, size=args.size,
                              seed=args.seed + 1_000_003)
    seqs = to_sequences(imgs)  # [n, T, 1]
    save_mtf(args.export, {
        "x": seqs[:, :, 0],    # [n, T]
        "y": labels,
    })
    print(f"exported {args.n} test sequences (T={args.size ** 2}) "
          f"to {args.export}")


if __name__ == "__main__":
    main()
