"""The MINIMALIST network (Layer 2): stacked minGRU blocks with the
paper's hardware constraints, in three variants matching Fig 5.

Variants
--------
``fp32``  — the baseline: full-precision weights/biases, the original
            minGRU activations (Feng et al. 2024): candidate activation
            g(u) = u + 0.5 for u ≥ 0 else σ(u), sigmoid gate, analog
            (identity) inter-layer activations. Paper: 98.1 % on sMNIST.
``quant`` — 2-bit weights, 6-bit biases, *binary* output activations;
            internal activations unchanged (sigmoid gate, g on h̃).
            Paper: 97.7 %.
``hw``    — fully hardware-compatible: additionally drops the candidate
            activation (h̃ is the raw IMC mean), replaces the gate sigmoid
            by the hard sigmoid (Eq. 5) quantized to 6 bits, and moves the
            h-bias into the output comparator threshold (paper §3.1.4).
            Paper: 96.9 %.

All variants share the IMC *mean* convention (DESIGN.md §5): projections
compute (1/N)·Σ — the charge-share semantics — with a trainable per-layer
gate gain ``alpha`` (realized in hardware by the ADC slope) and per-unit
gate offset ``beta`` (ADC DAC offset). The architecture is the paper's
feed-forward stack (Fig 1), default dims 1-64-64-64-64-10; classification
reads the analog hidden state of the final 10-unit layer at the last time
step (digitized once by reusing the z-ADC; argmax is gain-invariant).

Two execution paths:
  * ``forward_train`` — parallel over time (associative scan), STE
    quantizers, used by train.py.
  * ``forward_step`` / ``forward_sequence`` — the hardware-exact
    inference recurrence; with ``use_pallas=True`` the L1 kernels are
    inlined so they lower into the AOT HLO artifacts.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import quant
from .kernels import ref as kref
from .kernels.gate_update import gate_update as gate_update_pallas
from .kernels.imc_matmul import imc_matmul as imc_matmul_pallas
from .kernels.mingru_scan import mingru_layer_scan as mingru_scan_pallas

# "qw" (2-bit weights only) and "qwb" (+6-bit biases) are the intermediate
# stages of the paper's multi-stage QAT schedule (§4.1: "4 gradual phases
# of quantization-aware training"); Fig 5 reports fp32 / quant / hw.
VARIANTS = ("fp32", "qw", "qwb", "quant", "hw")
FIG5_VARIANTS = ("fp32", "quant", "hw")

# The classifier reads the mean of the readout layer's analog states over
# the final READOUT_STEPS time steps (digitized by reusing the z-ADC, ten
# channels × 8 conversions — negligible next to the T-step recurrence).
# Averaging a short tail instead of the single final state stabilizes
# training on long pixel sequences; argmax is invariant to the 1/K factor.
READOUT_STEPS = 8
DEFAULT_DIMS = (1, 64, 64, 64, 64, 10)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture + variant description."""

    dims: tuple[int, ...] = DEFAULT_DIMS
    variant: str = "hw"

    def __post_init__(self):
        assert self.variant in VARIANTS, self.variant
        assert len(self.dims) >= 2

    @property
    def n_layers(self) -> int:
        return len(self.dims) - 1

    @property
    def hidden_dims(self) -> tuple[int, ...]:
        return self.dims[1:]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0) -> list[dict[str, Any]]:
    """Per-layer parameter pytree.

    wh, wz: [N, H] projection weights; bh, bz: [H] biases;
    log_alpha: scalar log gate gain; gamma: scalar candidate gain
    (fp32/quant only — the hw variant has no gain on the h̃ path because
    the physical charge share provides none).
    """
    rng = np.random.default_rng(seed)
    params = []
    for n, h in zip(cfg.dims[:-1], cfg.dims[1:]):
        params.append({
            "wh": jnp.asarray(rng.normal(0.0, 1.0, (n, h)), jnp.float32),
            "wz": jnp.asarray(rng.normal(0.0, 1.0, (n, h)), jnp.float32),
            "bh": jnp.zeros((h,), jnp.float32),
            # Slow-gate initialization: with z ≈ σ(0) = 0.5 the state h_T
            # only integrates the last handful of steps (∏(1−z_s) decays
            # like 2^{−k}); starting the gate bias low gives units an
            # integration window comparable to the sequence length, the
            # standard recipe for pixel-level sequence tasks. The hw
            # variant must stay inside the hard sigmoid's live region
            # (hardsig(−4) is *exactly* 0 — gates would never open and
            # no events would ever be emitted).
            "bz": jnp.full((h,), -2.5 if cfg.variant == "hw" else -4.0,
                           jnp.float32),
            # the IMC mean has std ≈ std(w)·sqrt(p/N) (p = input activity);
            # alpha ~ sqrt(N) rescales the gate pre-activation to O(1).
            "log_alpha": jnp.asarray(np.log(1.5 * np.sqrt(n)), jnp.float32),
            "gamma": jnp.asarray(2.0 * np.sqrt(n), jnp.float32),
        })
    return params


def g_candidate(u: jax.Array) -> jax.Array:
    """Feng et al. (2024) continuous candidate activation g(·).

    g(u) = u + 0.5 for u ≥ 0, σ(u) otherwise — continuous at 0 (both
    branches give 0.5) and strictly positive, the form the minGRU paper
    uses so the log-space parallel scan is well-defined.
    """
    return jnp.where(u >= 0.0, u + 0.5, jax.nn.sigmoid(u))


# ---------------------------------------------------------------------------
# Effective (fake-quantized) layer parameters per variant
# ---------------------------------------------------------------------------


def effective_layer(cfg: ModelConfig, p: dict[str, Any], *, ste: bool):
    """Resolve a layer's raw parameters into the values the forward pass
    uses, applying the variant's quantizers (STE versions during
    training, pure versions for eval/export)."""
    w2 = quant.w2_ste if ste else quant.w2_q
    b6 = quant.b6_ste if ste else quant.b6_q
    if cfg.variant == "fp32":
        wh, wz = p["wh"], p["wz"]
    else:
        wh, wz = w2(p["wh"]), w2(p["wz"])
    if cfg.variant in ("fp32", "qw"):
        bh, bz = p["bh"], p["bz"]
    else:
        bh, bz = b6(p["bh"]), b6(p["bz"])
    alpha = jnp.exp(p["log_alpha"])
    return dict(wh=wh, wz=wz, bh=bh, bz=bz, alpha=alpha, gamma=p["gamma"])


def adapt_params(params: list[dict[str, Any]], logit_scale: jax.Array,
                 from_variant: str, to_variant: str):
    """Re-parameterize a checkpoint when the QAT schedule advances.

    All transitions are identity except entering ``hw``, which changes the
    layer function in two ways that need compensation:

    1. The candidate gain/activation disappears: earlier stages use
       h̃ ≈ γ·imc + b_h + 0.5 (positive branch of g), hw uses h̃ = imc.
       The state shrinks by γ, and the output threshold that keeps Θ(h)
       fixed is θ = −(b_h + 0.5)/γ (b_h is reinterpreted as the comparator
       threshold). The readout temperature grows by γ accordingly.

    2. The gate sigmoid becomes the hard sigmoid (Eq. 5). A slow gate
       (σ(b_z) ≈ 0.02 at b_z = −4) would land on hardsig's *dead zone*
       (hardsig(−4) = 0 exactly) and freeze every state. We linearize
       around the operating point: choose u' = a·(u − b_z) + u₀ with
       u₀ = 6·σ(b_z) − 3 (value match: hardsig(u₀) = σ(b_z)) and
       a = 6·σ'(b_z) (slope match), folding a into the shared ADC slope
       alpha via its per-layer mean.
    """
    n_layers = len(params)
    if to_variant == "quant" and from_variant == "qwb":
        # Binarization shock control: in qwb the state is
        # h ≈ mix(γ·imc) + (b_h + 0.5) with mix(γ·imc) roughly centered
        # at zero, so a comparator threshold of 0.5 starts Θ(h − θ) near
        # the 50 % firing point (θ = 0 would be constant-1: g ≥ 0). b_h
        # is re-purposed as the trainable threshold from there. The
        # readout layer is not binarized; its bias moves to the *digital*
        # domain (added to the averaged readout states), which is exact.
        new_params = []
        for li, p in enumerate(params):
            q = dict(p)
            if li < n_layers - 1:
                q["bh"] = jnp.full_like(p["bh"], 0.5)
            new_params.append(q)
        return new_params, logit_scale
    if to_variant != "hw" or from_variant == "hw":
        return params, logit_scale
    new_params = []
    for li, p in enumerate(params):
        q = dict(p)
        if li < n_layers - 1:
            # quant: h ≈ γ·h_hw + 0.5 (asymptotically; the +0.5 of g's
            # positive branch accumulates through the convex mixing), so
            # the threshold that keeps Θ(h − b_h) fixed is (b_h − 0.5)/γ.
            q["bh"] = (p["bh"] - 0.5) / p["gamma"]
        else:
            # readout: the digital bias tracks the shrink-by-γ with the
            # opposite sign of the 0.5 (it is *added*, not a threshold):
            # logits ∝ γ·h_hw + 0.5 + b_h.
            q["bh"] = (p["bh"] + 0.5) / p["gamma"]
        s = jax.nn.sigmoid(p["bz"])
        q["bz"] = 6.0 * s - 3.0
        a = jnp.mean(6.0 * s * (1.0 - s))
        q["log_alpha"] = p["log_alpha"] + jnp.log(jnp.maximum(a, 1e-3))
        new_params.append(q)
    return new_params, logit_scale * params[-1]["gamma"]


# ---------------------------------------------------------------------------
# Training-time forward (parallel scan over T)
# ---------------------------------------------------------------------------


def _layer_zh(cfg: ModelConfig, eff: dict[str, Any], x_seq: jax.Array):
    """Per-step gate z and candidate h̃ for a whole sequence (parallel).

    x_seq [T, B, N] → (z, htilde), each [T, B, H].
    """
    t, b, n = x_seq.shape
    flat = x_seq.reshape(t * b, n)
    imc_h = kref.imc_matmul_ref(flat, eff["wh"])
    imc_z = kref.imc_matmul_ref(flat, eff["wz"])
    h_dim = imc_h.shape[-1]
    imc_h = imc_h.reshape(t, b, h_dim)
    imc_z = imc_z.reshape(t, b, h_dim)

    u_z = eff["alpha"] * imc_z + eff["bz"]
    if cfg.variant == "hw":
        z = quant.z6_ste(quant.hard_sigmoid_ste(u_z))
        htilde = imc_h
    elif cfg.variant == "quant":
        # Binarized-output variant: the candidate bias moves to the output
        # comparator threshold (as in hw). Feng's g(·) is strictly
        # positive, so a zero-threshold Θ(h) would be constant 1 — the
        # threshold *must* carry the bias for the binary events to be
        # informative.
        z = jax.nn.sigmoid(u_z)
        htilde = g_candidate(eff["gamma"] * imc_h)
    else:
        z = jax.nn.sigmoid(u_z)
        htilde = g_candidate(eff["gamma"] * imc_h + eff["bh"])
    return z, htilde


def _layer_train(cfg: ModelConfig, eff: dict[str, Any],
                 x_seq: jax.Array) -> jax.Array:
    """One hidden layer, parallel over time, returning the inter-layer
    activation sequence [T, B, H]."""
    z, htilde = _layer_zh(cfg, eff, x_seq)
    h0 = jnp.zeros(htilde.shape[1:], jnp.float32)
    h_seq = kref.mingru_scan_ref(z, htilde, h0)
    if cfg.variant in ("fp32", "qw", "qwb"):
        return h_seq                       # analog inter-layer activations
    # quant & hw: binary events, comparator threshold carries b^h
    return quant.heaviside_ste(h_seq - eff["bh"])


def forward_train(cfg: ModelConfig, params: list[dict[str, Any]],
                  x_seq: jax.Array, logit_scale: jax.Array) -> jax.Array:
    """Training forward: x_seq [T, B, dims[0]] → logits [B, dims[-1]].

    The final layer's *analog* state at t=T−1 provides the logits (the
    binary output activation is not applied to the readout layer — the
    hardware digitizes the final h via the z-ADC instead). ``logit_scale``
    is a software-only temperature; argmax is invariant to it.
    """
    seq = x_seq
    for li, p in enumerate(params):
        eff = effective_layer(cfg, p, ste=True)
        if li == cfg.n_layers - 1:
            z, htilde = _layer_zh(cfg, eff, seq)
            h0 = jnp.zeros(htilde.shape[1:], jnp.float32)
            h_seq = kref.mingru_scan_ref(z, htilde, h0)
            readout = h_seq[-READOUT_STEPS:].mean(axis=0)
            if cfg.variant in ("quant", "hw"):
                # candidate bias is not physically realizable on the h̃
                # path; for the readout it is applied in the digital
                # domain after ADC conversion (exact, and free).
                readout = readout + eff["bh"]
            return logit_scale * readout
        seq = _layer_train(cfg, eff, seq)
    raise AssertionError("unreachable")


# ---------------------------------------------------------------------------
# Inference-time forward (hardware-exact recurrence; AOT export path)
# ---------------------------------------------------------------------------


def _layer_consts(cfg: ModelConfig, eff: dict[str, Any], last: bool):
    """Translate effective params into the (wh, wz, alpha, beta, theta)
    tuple the fused hardware step consumes (DESIGN.md §5 codesign map):
    beta = ADC offset (from b^z), theta = comparator reference (from
    b^h; unused for the readout layer, which is digitized, not
    thresholded)."""
    if cfg.variant != "hw":
        raise ValueError("hardware-exact inference requires variant='hw'")
    theta = jnp.zeros_like(eff["bh"]) if last else eff["bh"]
    return eff["wh"], eff["wz"], eff["alpha"], eff["bz"], theta


def forward_step(cfg: ModelConfig, params: list[dict[str, Any]],
                 x_t: jax.Array, h_all: list[jax.Array], *,
                 use_pallas: bool = True):
    """Single-time-step multi-layer update (the streaming request path).

    x_t: [B, dims[0]]; h_all: list of [B, H_l] per layer.
    Returns (readout [B, dims[-1]] analog states of the final layer,
    new h_all list, y_last [B, dims[-1]] binary outputs of the final
    layer — unused for classification but part of the event fabric).
    """
    eff_all = [effective_layer(cfg, p, ste=False) for p in params]
    x = x_t
    new_h = []
    for li, eff in enumerate(eff_all):
        last = li == cfg.n_layers - 1
        wh, wz, alpha, beta, theta = _layer_consts(cfg, eff, last)
        if use_pallas:
            imc_h = imc_matmul_pallas(x, wh)
            imc_z = imc_matmul_pallas(x, wz)
            z, h_new, y = gate_update_pallas(
                imc_z, imc_h, h_all[li], alpha, beta, theta)
        else:
            imc_h = kref.imc_matmul_ref(x, wh)
            imc_z = kref.imc_matmul_ref(x, wz)
            z, h_new, y = kref.gate_update_ref(
                imc_z, imc_h, h_all[li], alpha, beta, theta)
        new_h.append(h_new)
        x = y
    return new_h[-1], new_h, x


def forward_sequence(cfg: ModelConfig, params: list[dict[str, Any]],
                     x_seq: jax.Array, *, use_pallas: bool = True,
                     collect_traces: bool = False):
    """Hardware-exact full-sequence classification.

    x_seq [T, B, dims[0]] → logits [B, dims[-1]] (= final analog h of the
    readout layer). With collect_traces, also returns per-layer
    (z_seq, h_seq, y_seq) — the Fig 4 observables.
    """
    eff_all = [effective_layer(cfg, p, ste=False) for p in params]
    seq = x_seq
    traces = []
    logits = None
    for li, eff in enumerate(eff_all):
        last = li == cfg.n_layers - 1
        wh, wz, alpha, beta, theta = _layer_consts(cfg, eff, last)
        b = seq.shape[1]
        h0 = jnp.zeros((b, wh.shape[1]), jnp.float32)
        if use_pallas:
            z_seq, h_seq, y_seq = mingru_scan_pallas(
                seq, wh, wz, alpha, beta, theta, h0)
        else:
            z_seq, h_seq, y_seq = kref.mingru_layer_seq_ref(
                seq, wh, wz, alpha, beta, theta, h0)
        if collect_traces:
            traces.append((z_seq, h_seq, y_seq))
        if last:
            # digital readout: average the final analog states and add
            # the (digital) readout bias — matches forward_train's head.
            logits = h_seq[-READOUT_STEPS:].mean(axis=0) + eff["bh"]
        seq = y_seq
    if collect_traces:
        return logits, traces
    return logits


# ---------------------------------------------------------------------------
# Loss / metrics
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
