"""AOT lowering: the JAX model (with its Pallas kernels inlined) → HLO
text artifacts that the rust runtime loads through the PJRT C API.

Python runs ONCE, at build time. The rust binary is self-contained
afterwards: `artifacts/sequence.hlo.txt` (full-sequence classifier) and
`artifacts/step.hlo.txt` (single-step streaming update) embed the trained
weights as constants — one compiled executable per model variant, the
standard AOT serving pattern.

Interchange is HLO *text*, not `.serialize()`: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Also writes `artifacts/aot_smoke.mtf` with an example input and the
jax-evaluated output so the rust side can verify numerics end-to-end
(tests/aot_parity.rs), and `artifacts/meta.json` with the shapes.

Usage:
    python -m compile.aot --out-dir ../artifacts [--weights runs/hw_s0/weights.mtf]
                          [--batch 8] [--img-size 16]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .export import load_mtf, save_mtf


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def load_params(weights_path: str | None, dims):
    """Load a trained hw checkpoint, or fall back to a fresh init (smoke
    builds; documented as synthetic in meta.json)."""
    if weights_path and Path(weights_path).exists():
        t = load_mtf(weights_path)
        dims = tuple(int(d) for d in t["meta.dims"])
        params = []
        for l in range(len(dims) - 1):
            params.append({
                "wh": jnp.asarray(t[f"l{l}.wh"]),
                "wz": jnp.asarray(t[f"l{l}.wz"]),
                "bh": jnp.asarray(t[f"l{l}.bh"]),
                "bz": jnp.asarray(t[f"l{l}.bz"]),
                "log_alpha": jnp.log(jnp.asarray(t[f"l{l}.alpha"][0])),
                "gamma": jnp.asarray(t[f"l{l}.gamma"][0]),
            })
        return params, dims, True
    cfg = model_mod.ModelConfig(dims=dims, variant="hw")
    return model_mod.init_params(cfg, seed=0), dims, False


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--weights", default="../runs/hw_s0/weights.mtf")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--img-size", type=int, default=16)
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference instead of the "
                         "Pallas kernels (debugging aid)")
    args = ap.parse_args(argv)

    out = Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    t_len = args.img_size * args.img_size
    batch = args.batch

    params, dims, trained = load_params(args.weights, model_mod.DEFAULT_DIMS)
    cfg = model_mod.ModelConfig(dims=dims, variant="hw")
    use_pallas = not args.no_pallas

    # ---- sequence classifier: [T, B, d_in] → (logits [B, n_out],) ------
    def seq_fn(x_seq):
        return (model_mod.forward_sequence(
            cfg, params, x_seq, use_pallas=use_pallas),)

    seq_spec = jax.ShapeDtypeStruct((t_len, batch, dims[0]), jnp.float32)
    lowered_seq = jax.jit(seq_fn).lower(seq_spec)
    (out / "sequence.hlo.txt").write_text(to_hlo_text(lowered_seq))
    print(f"wrote sequence.hlo.txt  [T={t_len}, B={batch}] → [{batch}, {dims[-1]}]")

    # ---- single step: (x_t [B, d_in], h_1..h_L) → (readout, h_1'..h_L') -
    def step_fn(x_t, *h_all):
        readout, new_h, y_last = model_mod.forward_step(
            cfg, params, x_t, list(h_all), use_pallas=use_pallas)
        return (readout, *new_h)

    h_specs = [jax.ShapeDtypeStruct((batch, h), jnp.float32)
               for h in dims[1:]]
    x_spec = jax.ShapeDtypeStruct((batch, dims[0]), jnp.float32)
    lowered_step = jax.jit(step_fn).lower(x_spec, *h_specs)
    (out / "step.hlo.txt").write_text(to_hlo_text(lowered_step))
    print(f"wrote step.hlo.txt      [B={batch}] × {len(h_specs)} states")

    # ---- smoke vectors: example input + jax-evaluated output -----------
    rng = np.random.default_rng(0)
    x_ex = rng.random((t_len, batch, dims[0]), dtype=np.float32)
    logits_ex = np.asarray(jax.jit(seq_fn)(jnp.asarray(x_ex))[0])
    save_mtf(out / "aot_smoke.mtf", {
        "x": x_ex.reshape(t_len, batch * dims[0]),
        "logits": logits_ex,
    })
    print("wrote aot_smoke.mtf     (input + jax-evaluated logits)")

    meta = {
        "t_len": t_len, "batch": batch, "dims": list(dims),
        "variant": cfg.variant, "trained_weights": trained,
        "weights_path": args.weights if trained else None,
        "pallas": use_pallas,
    }
    (out / "meta.json").write_text(json.dumps(meta, indent=1))
    print(f"wrote meta.json         {meta}")


if __name__ == "__main__":
    main()
