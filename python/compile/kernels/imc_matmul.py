"""Pallas kernel for the charge-sharing IMC projection (paper Eq. 6).

The switched-capacitor array computes, per column j, the mean of the
weight-rail voltages selected by the active rows:

    imc_j = (1/N) · Σ_i x_i · q(w_ij)

On TPU this is a matmul with a binary (or first-layer analog) LHS and a
4-level RHS — MXU-friendly once the 2-bit codes are expanded to their
effective values. The kernel tiles the (N × M) weight matrix into VMEM
blocks and accumulates partial column sums over the row-block grid axis,
mirroring the segmented column structure of the physical array (the same
segmentation the ADC slope control exploits, Fig 3A).

Hardware adaptation note (DESIGN.md §3): the row-driver gating (x_i
selects rail V_w vs V_0) becomes a multiplicative mask on the LHS block;
the "1/N" charge-share normalization is folded into the epilogue of the
last row block rather than pre-scaling the weights, so the accumulator
keeps full precision — the analog array enjoys the same property (charge
accumulates exactly; division happens implicitly in the share).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _imc_kernel(x_ref, w_ref, o_ref, acc_ref, *, nsteps: int, n_total: int):
    """One (B-block × M-block) tile; grid axis 2 walks row blocks."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Row-driver gating × rail selection, accumulated in f32.
    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nsteps - 1)
    def _epilogue():
        # Charge-share normalization: the column settles to the *mean*.
        o_ref[...] = acc_ref[...] * (1.0 / n_total)


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "block_m"))
def imc_matmul(x: jax.Array, w_eff: jax.Array, *,
               block_b: int = 64, block_n: int = 128,
               block_m: int = 128) -> jax.Array:
    """Charge-sharing IMC matmul: (x @ w_eff) / N via a Pallas kernel.

    x:     [B, N] activations; w_eff: [N, M] effective weights.
    Blocks are clamped to the actual dims (the paper's cores are 64×64;
    a full 64×128 interleaved z/h̃ block fits VMEM comfortably).
    """
    b, n = x.shape
    n2, m = w_eff.shape
    assert n == n2, f"shape mismatch {x.shape} @ {w_eff.shape}"
    bb = min(block_b, b)
    bn = min(block_n, n)
    bm = min(block_m, m)
    # Pad every dim to a block multiple: interpret-mode Pallas fills
    # out-of-bounds block regions with NaN, so ragged tails must be
    # explicitly zero-padded (zeros are absorbed by the accumulation).
    bp = -b % bb
    np_ = -n % bn
    mp = -m % bm
    if bp or np_:
        x = jnp.pad(x, ((0, bp), (0, np_)))
    if np_ or mp:
        w_eff = jnp.pad(w_eff, ((0, np_), (0, mp)))
    grid = (pl.cdiv(b + bp, bb), pl.cdiv(m + mp, bm), pl.cdiv(n + np_, bn))

    out = pl.pallas_call(
        functools.partial(_imc_kernel, nsteps=grid[2], n_total=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bn), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bm), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b + bp, m + mp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, bm), jnp.float32)],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x, w_eff)
    return out[:b, :m]
