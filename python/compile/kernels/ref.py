"""Pure-jnp reference oracles for the MINIMALIST kernels.

These definitions are the *authoritative semantics* of the hardware
computation (DESIGN.md §5). The Pallas kernels in this package, the JAX
model, the rust golden model (`rust/src/nn/`) and the switched-capacitor
simulator (`rust/src/satsim/`) are all tested against — or derived from —
the functions in this file.

Logical units: the IMC charge share (paper Eq. 6) produces the *mean* of
the selected weight voltages. We work in "code units": an effective weight
q(w) ∈ {-1.5, -0.5, +0.5, +1.5} (the four equidistant rails around V_0)
and a column result imc = (1/N)·Σ_i x_i·q(w_ij) ∈ [-1.5, +1.5]. Hidden
states are convex mixtures of candidate states and therefore stay inside
the same range — exactly the property that lets the hardware keep them as
analog voltages on the sampling capacitors.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def imc_matmul_ref(x: jax.Array, w_eff: jax.Array) -> jax.Array:
    """Charge-sharing IMC projection (Eq. 6): column means of gated rails.

    x:     [B, N]  input activations (binary {0,1} for hidden layers; the
                   first layer's analog pixel x ∈ [0,1] is realized by the
                   row driver interpolating between V_0 and V_w).
    w_eff: [N, M]  effective weights q(codes) ∈ {-1.5,-0.5,0.5,1.5} (times
                   an optional shared scale folded in by the caller).
    returns [B, M] = (x @ w_eff) / N
    """
    n = x.shape[-1]
    return (x @ w_eff) / jnp.float32(n)


def hard_sigmoid_ref(u: jax.Array) -> jax.Array:
    """σ^z (Eq. 5)."""
    return jnp.clip(u / 6.0 + 0.5, 0.0, 1.0)


def z6_ref(z: jax.Array) -> jax.Array:
    """6-bit gate quantization: codes 0..63, value code/63."""
    return jnp.round(jnp.clip(z, 0.0, 1.0) * 63.0) / 63.0


def gate_update_ref(imc_z: jax.Array, imc_h: jax.Array, h_prev: jax.Array,
                    alpha: jax.Array, beta: jax.Array, theta: jax.Array):
    """Fused gate digitization + state update + output comparator.

    imc_z, imc_h: [B, H] raw IMC column means for the z and h̃ projections.
    h_prev:       [B, H] previous hidden state.
    alpha:        scalar — gate gain, realized by the ADC slope
                  (C_ADC/C_IMC segmentation, Fig 3).
    beta:         [H] — gate bias, realized by the ADC capacitive-DAC
                  offset pre-charge (per ADC channel).
    theta:        [H] — output threshold, realized by the comparator
                  reference (paper §3.1.4: bias on h subsumed there).

    Returns (z, h_new, y):
      z     = Q6(σ^z(alpha·imc_z + beta))        -- 6-bit gate
      h_new = z·imc_h + (1−z)·h_prev             -- Eq. 1 (capacitor swap)
      y     = Θ(h_new − theta)                   -- Eq. 4 (binary output)
    """
    z = z6_ref(hard_sigmoid_ref(alpha * imc_z + beta))
    h_new = z * imc_h + (1.0 - z) * h_prev
    y = (h_new > theta).astype(h_new.dtype)
    return z, h_new, y


def mingru_layer_seq_ref(x_seq: jax.Array, wh_eff: jax.Array,
                         wz_eff: jax.Array, alpha: jax.Array,
                         beta: jax.Array, theta: jax.Array,
                         h0: jax.Array):
    """Full-sequence hardware-exact layer forward (sequential recurrence).

    x_seq: [T, B, N] layer inputs; returns (z_seq, h_seq, y_seq) each
    [T, B, H]. This is the loop the mixed-signal core executes one time
    step at a time, and the oracle for kernels/mingru_scan.py.
    """

    def step(h_prev, x_t):
        imc_h = imc_matmul_ref(x_t, wh_eff)
        imc_z = imc_matmul_ref(x_t, wz_eff)
        z, h_new, y = gate_update_ref(imc_z, imc_h, h_prev,
                                      alpha, beta, theta)
        return h_new, (z, h_new, y)

    _, (z_seq, h_seq, y_seq) = jax.lax.scan(step, h0, x_seq)
    return z_seq, h_seq, y_seq


def mingru_scan_ref(z_seq: jax.Array, htilde_seq: jax.Array,
                    h0: jax.Array) -> jax.Array:
    """Parallel-scan evaluation of Eq. 1 given per-step z and h̃.

    h_t = z_t·h̃_t + (1−z_t)·h_{t−1} is a first-order linear recurrence
    h_t = a_t·h_{t−1} + b_t with a = 1−z, b = z·h̃ — associative, so it
    admits the log-depth parallel scan that makes minGRU training fast
    (the paper's training-efficiency premise).
    z_seq, htilde_seq: [T, B, H]; h0: [B, H]. Returns h_seq [T, B, H].
    """
    a = 1.0 - z_seq
    b = z_seq * htilde_seq

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_sc, b_sc = jax.lax.associative_scan(combine, (a, b), axis=0)
    return a_sc * h0[None] + b_sc
