"""Fused Pallas kernel: gate digitization + capacitor-swap state update +
output comparator (paper §3.1.2–§3.1.4).

One invocation fuses, per GRU unit:

    z     = Q6( σ^z( alpha·imc_z + beta ) )     -- SAR ADC with slope/offset
    h_new = z·imc_h + (1−z)·h_prev              -- capacitor-bank swap (Eq. 1)
    y     = Θ( h_new − theta )                  -- clocked comparator (Eq. 4)

Fusing matters on hardware and on TPU for the same reason: z is consumed
immediately where it is produced. The physical core never moves z off-chip
(the ADC output directly drives the swap switches S2^h); the kernel
likewise keeps z in VMEM and avoids an HBM round-trip between the ADC and
the state update. Everything here is elementwise → VPU work, so blocks are
sized to the (8, 128) VPU lanes rather than the MXU tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gate_update_kernel(imc_z_ref, imc_h_ref, h_prev_ref, alpha_ref,
                        beta_ref, theta_ref, z_ref, h_ref, y_ref):
    alpha = alpha_ref[0]
    u = alpha * imc_z_ref[...] + beta_ref[...]
    # σ^z hard sigmoid (Eq. 5) + 6-bit quantization: the ADC transfer curve.
    z = jnp.round(jnp.clip(u / 6.0 + 0.5, 0.0, 1.0) * 63.0) / 63.0
    h_new = z * imc_h_ref[...] + (1.0 - z) * h_prev_ref[...]
    z_ref[...] = z
    h_ref[...] = h_new
    y_ref[...] = (h_new > theta_ref[...]).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_b", "block_h"))
def gate_update(imc_z: jax.Array, imc_h: jax.Array, h_prev: jax.Array,
                alpha: jax.Array, beta: jax.Array, theta: jax.Array, *,
                block_b: int = 64, block_h: int = 128):
    """Fused ADC + state update + comparator. All array args [B, H].

    alpha is a scalar (per-layer ADC slope); beta/theta are [H]
    (per-channel ADC offset / comparator reference).
    Returns (z, h_new, y), each [B, H] f32.
    """
    b, h = imc_z.shape
    bb, bh = min(block_b, b), min(block_h, h)
    # zero-pad ragged tails (interpret-mode OOB blocks read as NaN)
    bp = -b % bb
    hp = -h % bh
    if bp or hp:
        pad2 = lambda a: jnp.pad(a, ((0, bp), (0, hp)))
        imc_z, imc_h, h_prev = pad2(imc_z), pad2(imc_h), pad2(h_prev)
        beta = jnp.pad(beta, (0, hp))
        theta = jnp.pad(theta, (0, hp))
    grid = (pl.cdiv(b + bp, bb), pl.cdiv(h + hp, bh))
    alpha_arr = jnp.reshape(alpha.astype(jnp.float32), (1,))
    bh_spec = pl.BlockSpec((bb, bh), lambda i, j: (i, j))
    vec_spec = pl.BlockSpec((bh,), lambda i, j: (j,))
    out_sds = jax.ShapeDtypeStruct((b + bp, h + hp), jnp.float32)

    z, h_new, y = pl.pallas_call(
        _gate_update_kernel,
        grid=grid,
        in_specs=[
            bh_spec, bh_spec, bh_spec,
            pl.BlockSpec((1,), lambda i, j: (0,)),   # alpha (scalar)
            vec_spec, vec_spec,                      # beta, theta
        ],
        out_specs=[bh_spec, bh_spec, bh_spec],
        out_shape=[out_sds, out_sds, out_sds],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(imc_z, imc_h, h_prev, alpha_arr, beta, theta)
    return z[:b, :h], h_new[:b, :h], y[:b, :h]
