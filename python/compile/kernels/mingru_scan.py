"""Fused full-sequence Pallas kernel for one MINIMALIST GRU layer.

This is the inference hot-spot: given the layer input sequence it executes
the whole T-step recurrence of one core in a single kernel invocation —
IMC projections, ADC gate digitization, capacitor-swap state update and
comparator output for every time step — so the hidden state h never
leaves VMEM between steps. That is the software image of the paper's
central claim: the state lives on the sampling capacitors and is never
buffered or moved.

Layout: the grid walks (batch blocks × hidden blocks); time is an inner
fori_loop. The interleaved W^z/W^h matrix of the physical core (Fig 2A)
maps to the two weight refs resident in VMEM for the whole sequence —
for a 64×64 core at f32 that is 2·64·64·4 B = 32 KiB of weights plus
states, far under the ~16 MiB VMEM budget (DESIGN.md §9).

Note: columns are blocked, rows (the input dim N) are not — each hidden
block needs the full input row, exactly like the physical column needs
all N row drivers. N ≤ 64 per core makes this the natural tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mingru_scan_kernel(x_ref, wh_ref, wz_ref, alpha_ref, beta_ref,
                        theta_ref, h0_ref, z_ref, h_ref, y_ref,
                        *, t_len: int, n_total: int):
    alpha = alpha_ref[0]
    inv_n = 1.0 / n_total

    def step(t, h_prev):
        x_t = x_ref[t]                                     # [bb, N]
        imc_h = jnp.dot(x_t, wh_ref[...],
                        preferred_element_type=jnp.float32) * inv_n
        imc_z = jnp.dot(x_t, wz_ref[...],
                        preferred_element_type=jnp.float32) * inv_n
        u = alpha * imc_z + beta_ref[...]
        z = jnp.round(jnp.clip(u / 6.0 + 0.5, 0.0, 1.0) * 63.0) / 63.0
        h_new = z * imc_h + (1.0 - z) * h_prev
        z_ref[t] = z
        h_ref[t] = h_new
        y_ref[t] = (h_new > theta_ref[...]).astype(jnp.float32)
        return h_new

    jax.lax.fori_loop(0, t_len, step, h0_ref[...])


@functools.partial(jax.jit, static_argnames=("block_b", "block_h"))
def mingru_layer_scan(x_seq: jax.Array, wh_eff: jax.Array,
                      wz_eff: jax.Array, alpha: jax.Array,
                      beta: jax.Array, theta: jax.Array, h0: jax.Array, *,
                      block_b: int = 32, block_h: int = 128):
    """Hardware-exact full-sequence layer forward.

    x_seq:  [T, B, N] layer input (binary events; analog for layer 0).
    wh_eff, wz_eff: [N, H] effective weights.
    alpha: scalar; beta, theta: [H]; h0: [B, H].
    Returns (z_seq, h_seq, y_seq), each [T, B, H] f32.
    """
    t_len, b, n = x_seq.shape
    h = wh_eff.shape[1]
    bb, bh = min(block_b, b), min(block_h, h)
    # zero-pad ragged tails (interpret-mode OOB blocks read as NaN)
    bp = -b % bb
    hp = -h % bh
    if bp:
        x_seq = jnp.pad(x_seq, ((0, 0), (0, bp), (0, 0)))
        h0 = jnp.pad(h0, ((0, bp), (0, 0)))
    if hp:
        wh_eff = jnp.pad(wh_eff, ((0, 0), (0, hp)))
        wz_eff = jnp.pad(wz_eff, ((0, 0), (0, hp)))
        beta = jnp.pad(beta, (0, hp))
        theta = jnp.pad(theta, (0, hp))
        h0 = jnp.pad(h0, ((0, 0), (0, hp)))
    grid = (pl.cdiv(b + bp, bb), pl.cdiv(h + hp, bh))
    alpha_arr = jnp.reshape(alpha.astype(jnp.float32), (1,))

    seq_out = pl.BlockSpec((t_len, bb, bh), lambda i, j: (0, i, j))
    out_sds = jax.ShapeDtypeStruct((t_len, b + bp, h + hp), jnp.float32)

    z_seq, h_seq, y_seq = pl.pallas_call(
        functools.partial(_mingru_scan_kernel, t_len=t_len, n_total=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_len, bb, n), lambda i, j: (0, i, 0)),  # x_seq
            pl.BlockSpec((n, bh), lambda i, j: (0, j)),            # wh
            pl.BlockSpec((n, bh), lambda i, j: (0, j)),            # wz
            pl.BlockSpec((1,), lambda i, j: (0,)),                 # alpha
            pl.BlockSpec((bh,), lambda i, j: (j,)),                # beta
            pl.BlockSpec((bh,), lambda i, j: (j,)),                # theta
            pl.BlockSpec((bb, bh), lambda i, j: (i, j)),           # h0
        ],
        out_specs=[seq_out, seq_out, seq_out],
        out_shape=[out_sds, out_sds, out_sds],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x_seq, wh_eff, wz_eff, alpha_arr, beta, theta, h0)
    return z_seq[:, :b, :h], h_seq[:, :b, :h], y_seq[:, :b, :h]
