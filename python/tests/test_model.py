"""Model-level tests: variant semantics, train/inference-path agreement,
QAT hand-over re-parameterizations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

DIMS = (1, 12, 10)


def make(variant, seed=0):
    cfg = M.ModelConfig(dims=DIMS, variant=variant)
    return cfg, M.init_params(cfg, seed=seed)


def rand_seq(t=20, b=3, d=1, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.random((t, b, d)), jnp.float32)


class TestVariants:
    @pytest.mark.parametrize("variant", M.VARIANTS)
    def test_forward_shapes(self, variant):
        cfg, params = make(variant)
        x = rand_seq()
        logits = M.forward_train(cfg, params, x, jnp.float32(1.0))
        assert logits.shape == (3, 10)
        assert np.all(np.isfinite(np.array(logits)))

    @pytest.mark.parametrize("variant", M.VARIANTS)
    def test_gradients_flow_to_all_params(self, variant):
        cfg, params = make(variant)
        x = rand_seq(t=8)
        labels = jnp.asarray([0, 1, 2])

        def loss(params):
            return M.cross_entropy(
                M.forward_train(cfg, params, x, jnp.float32(1.0)), labels)

        grads = jax.grad(loss)(params)
        for li, g in enumerate(grads):
            for k in ("wh", "wz", "bz"):
                norm = float(jnp.abs(g[k]).sum())
                assert norm > 0.0, f"no gradient for layer {li} {k} ({variant})"

    def test_hw_z_is_quantized(self):
        cfg, params = make("hw")
        eff = M.effective_layer(cfg, params[0], ste=False)
        x = rand_seq(t=5)
        z, _ = M._layer_zh(cfg, eff, x)
        codes = np.array(z) * 63.0
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)

    def test_binary_variants_emit_binary_events(self):
        for variant in ("quant", "hw"):
            cfg, params = make(variant)
            eff = M.effective_layer(cfg, params[0], ste=False)
            out = M._layer_train(cfg, eff, rand_seq(t=6))
            vals = np.unique(np.array(out))
            assert set(vals.tolist()) <= {0.0, 1.0}, variant

    def test_fp32_passes_analog(self):
        cfg, params = make("fp32")
        eff = M.effective_layer(cfg, params[0], ste=False)
        out = np.array(M._layer_train(cfg, eff, rand_seq(t=6)))
        assert len(np.unique(out)) > 2


class TestInferencePath:
    def test_sequence_matches_train_forward_hw(self):
        """forward_train (parallel scan) and forward_sequence (hardware
        recurrence, pallas) must produce identical logits for hw."""
        cfg, params = make("hw")
        x = rand_seq(t=16, b=2)
        lt = M.forward_train(cfg, params, x, jnp.float32(1.0))
        ls = M.forward_sequence(cfg, params, x, use_pallas=True)
        np.testing.assert_allclose(np.array(lt), np.array(ls),
                                   rtol=1e-4, atol=1e-5)

    def test_step_equals_sequence(self):
        cfg, params = make("hw")
        x = rand_seq(t=10, b=2)
        _, traces = M.forward_sequence(cfg, params, x, use_pallas=False,
                                       collect_traces=True)
        h_all = [jnp.zeros((2, h), jnp.float32) for h in cfg.hidden_dims]
        for t in range(10):
            _, h_all, _ = M.forward_step(cfg, params, x[t], h_all,
                                         use_pallas=False)
        # final hidden state of every layer must match the sequence run
        for li in range(cfg.n_layers):
            np.testing.assert_allclose(
                np.array(h_all[li]),
                np.array(traces[li][1][-1]),
                rtol=1e-5, atol=1e-6)

    def test_non_hw_variant_rejected(self):
        cfg, params = make("quant")
        with pytest.raises(ValueError):
            M.forward_sequence(cfg, params, rand_seq(t=4))


class TestAdaptParams:
    def test_identity_transitions(self):
        _, params = make("fp32")
        ls = jnp.float32(2.0)
        p2, ls2 = M.adapt_params(params, ls, "fp32", "qw")
        assert float(ls2) == 2.0
        np.testing.assert_array_equal(np.array(p2[0]["bh"]),
                                      np.array(params[0]["bh"]))

    def test_quant_transition_centers_thresholds(self):
        _, params = make("qwb")
        p2, _ = M.adapt_params(params, jnp.float32(1.0), "qwb", "quant")
        for p in p2[:-1]:
            np.testing.assert_allclose(np.array(p["bh"]), 0.5)
        # readout layer keeps its bias
        np.testing.assert_array_equal(np.array(p2[-1]["bh"]),
                                      np.array(params[-1]["bh"]))

    def test_hw_transition_escapes_dead_zone(self):
        """σ(b_z)→hardsig remap must keep gates alive: with the slow-gate
        init b_z=−4, a naive carry-over lands on hardsig's hard zero."""
        _, params = make("quant")
        p2, _ = M.adapt_params(params, jnp.float32(1.0), "quant", "hw")
        for p in p2:
            bz = np.array(p["bz"])
            assert np.all(bz > -3.0), "gate stuck in hardsig dead zone"
            # operating point preserved: hardsig(bz') ≈ σ(bz)
            want = 1 / (1 + np.exp(4.0))
            got = np.clip(bz / 6.0 + 0.5, 0, 1)
            np.testing.assert_allclose(got, want, atol=1e-3)

    def test_hw_transition_rescales_logit_scale(self):
        _, params = make("quant")
        gamma = float(params[-1]["gamma"])
        _, ls2 = M.adapt_params(params, jnp.float32(3.0), "quant", "hw")
        assert abs(float(ls2) - 3.0 * gamma) < 1e-4


def test_g_candidate_is_continuous_and_positive():
    u = jnp.asarray(np.linspace(-5, 5, 201), jnp.float32)
    g = np.array(M.g_candidate(u))
    assert np.all(g > 0)
    assert np.all(np.abs(np.diff(g)) < 0.06)  # no jumps
    assert abs(float(M.g_candidate(jnp.float32(0.0))) - 0.5) < 1e-6
