"""Quantizer unit + property tests (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant

F32 = st.floats(-10.0, 10.0, allow_nan=False, width=32)


class TestW2:
    def test_levels_are_the_four_rails(self):
        w = jnp.asarray([-5.0, -0.6, 0.1, 5.0], jnp.float32)
        s = quant.weight_scale(w)
        codes = quant.w2_codes(w, s)
        assert codes.tolist() == [0, 1, 2, 3]
        deq = quant.w2_dequant(codes, s)
        np.testing.assert_allclose(
            np.array(deq) / float(s), [-1.5, -0.5, 0.5, 1.5])

    def test_no_zero_level(self):
        # the paper's rails are symmetric around V_0 with no exact zero
        w = jnp.zeros((8,), jnp.float32) + 1e-9
        q = quant.w2_q(w)
        assert np.all(np.array(q) != 0.0)

    @given(st.lists(F32, min_size=2, max_size=64))
    @settings(max_examples=100, deadline=None)
    def test_idempotent_at_fixed_scale(self, values):
        # Idempotence holds per scale (the data-derived scale itself
        # shifts after quantization, which is fine — codes are stable).
        w = jnp.asarray(values, jnp.float32)
        s = quant.weight_scale(w)
        q1 = quant.w2_dequant(quant.w2_codes(w, s), s)
        q2 = quant.w2_dequant(quant.w2_codes(q1, s), s)
        np.testing.assert_allclose(np.array(q1), np.array(q2), atol=1e-6)

    def test_ste_gradient_is_straight_through(self):
        g = jax.grad(lambda w: jnp.sum(quant.w2_ste(w)))(
            jnp.asarray([0.3, -0.2, 2.0], jnp.float32))
        np.testing.assert_allclose(np.array(g), 1.0, atol=1e-6)


class TestB6:
    @given(st.lists(F32, min_size=1, max_size=64))
    @settings(max_examples=100, deadline=None)
    def test_codes_in_range(self, values):
        b = jnp.asarray(values, jnp.float32)
        s = quant.bias_scale(b)
        codes = np.array(quant.b6_codes(b, s))
        assert codes.min() >= -32 and codes.max() <= 31

    def test_constant_vector_survives(self):
        # regression: a σ-based scale collapsed constant biases to zero
        b = jnp.full((16,), -4.0, jnp.float32)
        q = quant.b6_q(b)
        np.testing.assert_allclose(np.array(q), -4.0, rtol=0.05)


class TestGate:
    def test_hard_sigmoid_matches_eq5(self):
        u = jnp.asarray([-10.0, -3.0, 0.0, 1.5, 3.0, 10.0], jnp.float32)
        z = quant.hard_sigmoid(u)
        np.testing.assert_allclose(
            np.array(z), [0.0, 0.0, 0.5, 0.75, 1.0, 1.0], atol=1e-6)

    @given(st.floats(0.0, 1.0, allow_nan=False))
    @settings(max_examples=200, deadline=None)
    def test_z6_grid(self, z):
        q = float(quant.z6_q(jnp.float32(z)))
        code = round(q * 63.0)
        assert abs(q - code / 63.0) < 1e-6
        assert abs(q - z) <= 0.5 / 63.0 + 1e-6

    @given(st.floats(-2.0, 2.0, allow_nan=False), st.floats(-2.0, 2.0, allow_nan=False))
    @settings(max_examples=100, deadline=None)
    def test_z6_monotone(self, a, b):
        lo, hi = min(a, b), max(a, b)
        assert float(quant.z6_q(jnp.float32(lo))) <= float(
            quant.z6_q(jnp.float32(hi)))


class TestHeaviside:
    def test_forward_is_binary(self):
        h = jnp.asarray([-1.0, -1e-9, 0.0, 1e-9, 2.0], jnp.float32)
        y = quant.heaviside_ste(h)
        assert np.array(y).tolist() == [0.0, 0.0, 0.0, 1.0, 1.0]
        assert np.array_equal(np.array(quant.heaviside(h)), np.array(y))

    def test_surrogate_gradient_is_triangular(self):
        g = jax.grad(lambda h: jnp.sum(quant.heaviside_ste(h)))(
            jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0], jnp.float32))
        np.testing.assert_allclose(
            np.array(g), [0.0, 0.5, 1.0, 0.5, 0.0], atol=1e-6)


@pytest.mark.parametrize("fn", [quant.ste_round, lambda x: quant.ste_clip(x, -1, 1)])
def test_ste_helpers_have_identity_gradient(fn):
    g = jax.grad(lambda x: jnp.sum(fn(x)))(
        jnp.asarray([-3.0, 0.2, 3.0], jnp.float32))
    np.testing.assert_allclose(np.array(g), 1.0, atol=1e-6)
