"""synthMNIST generator tests: determinism, balance, encoding."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import data


def test_deterministic_given_seed():
    a, la = data.make_split(40, size=8, seed=3)
    b, lb = data.make_split(40, size=8, seed=3)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)


def test_different_seeds_differ():
    a, _ = data.make_split(10, size=8, seed=1)
    b, _ = data.make_split(10, size=8, seed=2)
    assert not np.array_equal(a, b)


def test_class_balance():
    _, labels = data.make_split(100, size=8, seed=0)
    counts = np.bincount(labels, minlength=10)
    assert np.all(counts == 10)


def test_pixel_range_and_ink():
    imgs, _ = data.make_split(20, size=16, seed=5)
    assert imgs.min() >= 0.0 and imgs.max() <= 1.0
    # every digit leaves a visible trace
    assert np.all(imgs.reshape(20, -1).sum(axis=1) > 5.0)


def test_sequence_encoding_is_row_major_scan():
    imgs, _ = data.make_split(3, size=8, seed=7)
    seqs = data.to_sequences(imgs)
    assert seqs.shape == (3, 64, 1)
    np.testing.assert_array_equal(seqs[1, :, 0], imgs[1].reshape(-1))


@given(n=st.integers(1, 30), size=st.sampled_from([8, 12, 16]))
@settings(max_examples=10, deadline=None)
def test_shapes_for_any_split(n, size):
    imgs, labels = data.make_split(n, size=size, seed=1)
    assert imgs.shape == (n, size, size)
    assert labels.shape == (n,)
    assert labels.min() >= 0 and labels.max() <= 9


def test_glyphs_distinct_across_classes():
    # clean templates of different digits must differ substantially
    rng_img = {d: data.make_glyph(d, size=16, seed=0, index=0, noise=0.0)
               for d in range(10)}
    for d1 in range(10):
        for d2 in range(d1 + 1, 10):
            diff = np.abs(rng_img[d1] - rng_img[d2]).mean()
            assert diff > 0.01, f"digits {d1} and {d2} identical"
