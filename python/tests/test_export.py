"""MTF container round-trip tests (python side of the cross-language
contract; the rust side lives in rust/tests/mtf_roundtrip.rs)."""

import numpy as np
import pytest

from compile.export import load_mtf, save_mtf


def test_roundtrip_all_dtypes(tmp_path):
    tensors = {
        "f32": np.linspace(-1, 1, 12, dtype=np.float32).reshape(3, 4),
        "i32": np.arange(-5, 5, dtype=np.int32),
        "u8": np.frombuffer(b"hello", dtype=np.uint8).copy(),
        "i64": np.asarray([2**40, -(2**40)], np.int64),
        "f64": np.asarray([[0.25]], np.float64),
    }
    p = tmp_path / "t.mtf"
    save_mtf(p, tensors)
    back = load_mtf(p)
    assert list(back) == list(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_dtype_normalization(tmp_path):
    p = tmp_path / "n.mtf"
    save_mtf(p, {
        "bool": np.asarray([True, False]),
        "i16": np.asarray([1, 2], np.int16),
        "f16": np.asarray([0.5], np.float16),
    })
    back = load_mtf(p)
    assert back["bool"].dtype == np.uint8
    assert back["i16"].dtype == np.int32
    assert back["f16"].dtype == np.float32


def test_scalar_and_empty(tmp_path):
    p = tmp_path / "s.mtf"
    save_mtf(p, {"s": np.asarray([3.5], np.float32),
                 "e": np.zeros((0,), np.float32)})
    back = load_mtf(p)
    assert back["s"][0] == np.float32(3.5)
    assert back["e"].size == 0


def test_bad_magic_rejected(tmp_path):
    p = tmp_path / "bad.mtf"
    p.write_bytes(b"NOPE" + b"\0" * 16)
    with pytest.raises(ValueError):
        load_mtf(p)


def test_checkpoint_schema(tmp_path):
    """export_checkpoint writes everything the rust loader requires."""
    import jax.numpy as jnp

    from compile import model as M
    from compile.train import export_checkpoint

    cfg = M.ModelConfig(dims=(1, 6, 10), variant="hw")
    params = M.init_params(cfg, seed=0)
    path = tmp_path / "w.mtf"
    export_checkpoint(cfg, params, jnp.float32(2.0), path)
    t = load_mtf(path)
    assert list(t["meta.dims"]) == [1, 6, 10]
    for li in range(2):
        for k in ("wh_codes", "wz_codes", "bh_codes", "bz_codes",
                  "wh_scale", "wz_scale", "bh_scale", "bz_scale", "alpha"):
            assert f"l{li}.{k}" in t, f"missing l{li}.{k}"
        codes = t[f"l{li}.wh_codes"]
        assert codes.min() >= 0 and codes.max() <= 3
