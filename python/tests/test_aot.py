"""AOT pipeline smoke: lowering produces parseable HLO text whose
jax-side evaluation matches the model (the rust-side parity lives in
rust/tests/aot_parity.rs). Uses a tiny geometry to stay fast."""

import numpy as np
import pytest

pytestmark = pytest.mark.filterwarnings("ignore")


def test_aot_generates_artifacts(tmp_path):
    from compile import aot

    aot.main([
        "--out-dir", str(tmp_path),
        "--weights", "/nonexistent",  # force synthetic init
        "--batch", "2",
        "--img-size", "4",
    ])
    seq = (tmp_path / "sequence.hlo.txt").read_text()
    step = (tmp_path / "step.hlo.txt").read_text()
    assert "HloModule" in seq and "HloModule" in step
    # the charge-share normalization constant must appear somewhere
    assert "f32" in seq
    meta = (tmp_path / "meta.json").read_text()
    assert '"t_len": 16' in meta
    assert (tmp_path / "aot_smoke.mtf").exists()


def test_smoke_vectors_match_fresh_eval(tmp_path):
    import jax.numpy as jnp

    from compile import aot
    from compile import model as M
    from compile.export import load_mtf

    aot.main([
        "--out-dir", str(tmp_path),
        "--weights", "/nonexistent",
        "--batch", "2",
        "--img-size", "4",
    ])
    smoke = load_mtf(tmp_path / "aot_smoke.mtf")
    x = smoke["x"].reshape(16, 2, 1)
    cfg = M.ModelConfig(dims=M.DEFAULT_DIMS, variant="hw")
    params = M.init_params(cfg, seed=0)
    logits = M.forward_sequence(cfg, params, jnp.asarray(x), use_pallas=True)
    np.testing.assert_allclose(np.array(logits), smoke["logits"],
                               rtol=1e-5, atol=1e-6)


def test_hlo_text_has_no_serialized_proto_markers(tmp_path):
    """Guard the interchange contract: text, not serialized protos."""
    from compile import aot

    aot.main(["--out-dir", str(tmp_path), "--weights", "/nonexistent",
              "--batch", "1", "--img-size", "4"])
    head = (tmp_path / "sequence.hlo.txt").read_bytes()[:64]
    assert head.lstrip().startswith(b"HloModule"), head
