"""Training-infrastructure tests: the hand-rolled Adam, the lr schedule,
and checkpoint save/load round-trips."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.train import (adam_init, adam_update, cosine_lr,
                           export_checkpoint, load_checkpoint)


class TestAdam:
    def test_converges_on_quadratic(self):
        # minimize ||x - target||² — Adam must get there quickly
        target = jnp.asarray([1.0, -2.0, 3.0], jnp.float32)
        params = {"x": jnp.zeros(3, jnp.float32)}
        opt = adam_init(params)

        def loss_fn(p):
            return jnp.sum((p["x"] - target) ** 2)

        for _ in range(300):
            grads = jax.grad(loss_fn)(params)
            opt, params = adam_update(opt, grads, params, lr=0.05)
        np.testing.assert_allclose(np.array(params["x"]), np.array(target),
                                   atol=1e-2)

    def test_bias_correction_first_step(self):
        # after one step the update magnitude must be ≈ lr (Adam property)
        params = {"x": jnp.zeros(1, jnp.float32)}
        opt = adam_init(params)
        grads = {"x": jnp.asarray([7.0], jnp.float32)}
        opt, params = adam_update(opt, grads, params, lr=0.01)
        assert abs(abs(float(params["x"][0])) - 0.01) < 1e-4

    def test_state_shapes_match_params(self):
        params = M.init_params(M.ModelConfig(dims=(1, 4, 10)), seed=0)
        opt = adam_init(params)
        flat_p = jax.tree_util.tree_leaves(params)
        flat_m = jax.tree_util.tree_leaves(opt["m"])
        assert len(flat_p) == len(flat_m)
        for p, m in zip(flat_p, flat_m):
            assert p.shape == m.shape


class TestCosineLr:
    def test_endpoints(self):
        assert abs(cosine_lr(1e-2, 0, 100) - 1e-2) < 1e-9
        assert abs(cosine_lr(1e-2, 100, 100) - 1e-3) < 1e-9  # floor 0.1×

    def test_monotone_decreasing(self):
        vals = [cosine_lr(1e-2, s, 50) for s in range(51)]
        assert all(a >= b - 1e-12 for a, b in zip(vals, vals[1:]))

    def test_clamps_past_total(self):
        assert cosine_lr(1e-2, 500, 100) == cosine_lr(1e-2, 100, 100)


class TestCheckpointRoundtrip:
    def test_save_load_identity(self, tmp_path):
        cfg = M.ModelConfig(dims=(1, 8, 10), variant="hw")
        params = M.init_params(cfg, seed=3)
        ls = jnp.float32(12.5)
        path = tmp_path / "w.mtf"
        export_checkpoint(cfg, params, ls, path)
        dims, variant, params2, ls2 = load_checkpoint(path)
        assert dims == (1, 8, 10)
        assert variant == "hw"
        assert abs(float(ls2) - 12.5) < 1e-6
        for p, q in zip(params, params2):
            for k in ("wh", "wz", "bh", "bz"):
                np.testing.assert_allclose(np.array(p[k]), np.array(q[k]),
                                           rtol=1e-6)
            np.testing.assert_allclose(float(jnp.exp(p["log_alpha"])),
                                       float(jnp.exp(q["log_alpha"])),
                                       rtol=1e-5)

    def test_forward_identical_after_roundtrip(self, tmp_path):
        cfg = M.ModelConfig(dims=(1, 8, 10), variant="hw")
        params = M.init_params(cfg, seed=4)
        path = tmp_path / "w.mtf"
        export_checkpoint(cfg, params, jnp.float32(1.0), path)
        _, _, params2, _ = load_checkpoint(path)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.random((12, 2, 1)), jnp.float32)
        a = M.forward_train(cfg, params, x, jnp.float32(1.0))
        b = M.forward_train(cfg, params2, x, jnp.float32(1.0))
        np.testing.assert_allclose(np.array(a), np.array(b),
                                   rtol=1e-5, atol=1e-6)
