"""Pallas kernels vs the pure-jnp oracle (the core L1 correctness
signal), including hypothesis sweeps over shapes and value regimes."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gate_update import gate_update
from compile.kernels.imc_matmul import imc_matmul
from compile.kernels.mingru_scan import mingru_layer_scan


def rand_w_eff(rng, n, m):
    """Effective 2-bit weights: (code−1.5)·scale."""
    return ((rng.integers(0, 4, (n, m)) - 1.5) * 0.8).astype(np.float32)


class TestImcMatmul:
    @given(
        b=st.integers(1, 17),
        n=st.integers(1, 130),
        m=st.integers(1, 140),
        binary=st.booleans(),
    )
    @settings(max_examples=25, deadline=None)
    def test_matches_ref_over_shapes(self, b, n, m, binary):
        rng = np.random.default_rng(b * 1000 + n * 10 + m)
        x = (rng.random((b, n)) < 0.4 if binary else rng.random((b, n))).astype(np.float32)
        w = rand_w_eff(rng, n, m)
        out = imc_matmul(jnp.asarray(x), jnp.asarray(w))
        want = ref.imc_matmul_ref(jnp.asarray(x), jnp.asarray(w))
        np.testing.assert_allclose(np.array(out), np.array(want),
                                   rtol=1e-5, atol=1e-6)

    def test_mean_semantics(self):
        # column of all +1.5·s rails with half the rows on → 0.75·s
        x = jnp.asarray([[1.0, 0.0, 1.0, 0.0]], jnp.float32)
        w = jnp.full((4, 1), 1.2, jnp.float32)  # 1.5 · 0.8
        out = imc_matmul(x, w)
        np.testing.assert_allclose(np.array(out), [[0.6]], rtol=1e-6)

    def test_block_boundaries(self):
        # shapes straddling the default 128-block boundaries
        rng = np.random.default_rng(0)
        for n, m in [(127, 129), (128, 128), (129, 127), (256, 3)]:
            x = rng.random((3, n)).astype(np.float32)
            w = rand_w_eff(rng, n, m)
            out = imc_matmul(jnp.asarray(x), jnp.asarray(w))
            want = ref.imc_matmul_ref(jnp.asarray(x), jnp.asarray(w))
            np.testing.assert_allclose(np.array(out), np.array(want),
                                       rtol=1e-5, atol=1e-6)


class TestGateUpdate:
    @given(b=st.integers(1, 9), h=st.integers(1, 200))
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, b, h):
        rng = np.random.default_rng(b * 211 + h)
        mk = lambda: jnp.asarray(rng.normal(0, 0.5, (b, h)), jnp.float32)
        imc_z, imc_h, h_prev = mk(), mk(), mk()
        alpha = jnp.float32(rng.uniform(0.5, 20.0))
        beta = jnp.asarray(rng.normal(0, 1, (h,)), jnp.float32)
        theta = jnp.asarray(rng.normal(0, 0.2, (h,)), jnp.float32)
        out = gate_update(imc_z, imc_h, h_prev, alpha, beta, theta)
        want = ref.gate_update_ref(imc_z, imc_h, h_prev, alpha, beta, theta)
        for a, b_ in zip(out, want):
            np.testing.assert_allclose(np.array(a), np.array(b_),
                                       rtol=1e-5, atol=1e-6)

    def test_z_is_on_6bit_grid(self):
        rng = np.random.default_rng(1)
        imc = jnp.asarray(rng.normal(0, 1, (4, 33)), jnp.float32)
        z, _, _ = gate_update(imc, imc, imc, jnp.float32(5.0),
                              jnp.zeros((33,)), jnp.zeros((33,)))
        codes = np.array(z) * 63.0
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)

    def test_state_is_convex_mixture(self):
        rng = np.random.default_rng(2)
        imc_h = jnp.asarray(rng.uniform(-1, 1, (2, 16)), jnp.float32)
        h_prev = jnp.asarray(rng.uniform(-1, 1, (2, 16)), jnp.float32)
        imc_z = jnp.asarray(rng.normal(0, 2, (2, 16)), jnp.float32)
        _, h_new, _ = gate_update(imc_z, imc_h, h_prev, jnp.float32(3.0),
                                  jnp.zeros((16,)), jnp.zeros((16,)))
        lo = np.minimum(np.array(imc_h), np.array(h_prev)) - 1e-6
        hi = np.maximum(np.array(imc_h), np.array(h_prev)) + 1e-6
        assert np.all(np.array(h_new) >= lo) and np.all(np.array(h_new) <= hi)


class TestMingruScan:
    @given(t=st.integers(1, 24), b=st.integers(1, 5), n=st.integers(1, 40),
           h=st.integers(1, 72))
    @settings(max_examples=15, deadline=None)
    def test_matches_sequential_ref(self, t, b, n, h):
        rng = np.random.default_rng(t * 7 + b * 3 + n + h)
        x = (rng.random((t, b, n)) < 0.35).astype(np.float32)
        wh = jnp.asarray(rand_w_eff(rng, n, h))
        wz = jnp.asarray(rand_w_eff(rng, n, h))
        alpha = jnp.float32(rng.uniform(1.0, 15.0))
        beta = jnp.asarray(rng.normal(-1, 1, (h,)), jnp.float32)
        theta = jnp.asarray(rng.normal(0, 0.1, (h,)), jnp.float32)
        h0 = jnp.zeros((b, h), jnp.float32)
        out = mingru_layer_scan(jnp.asarray(x), wh, wz, alpha, beta, theta, h0)
        want = ref.mingru_layer_seq_ref(jnp.asarray(x), wh, wz, alpha, beta,
                                        theta, h0)
        for a, b_ in zip(out, want):
            np.testing.assert_allclose(np.array(a), np.array(b_),
                                       rtol=1e-5, atol=1e-6)


class TestParallelScan:
    @given(t=st.integers(1, 50), b=st.integers(1, 4), h=st.integers(1, 32))
    @settings(max_examples=20, deadline=None)
    def test_associative_scan_equals_loop(self, t, b, h):
        rng = np.random.default_rng(t + b + h)
        z = jnp.asarray(rng.uniform(0, 1, (t, b, h)), jnp.float32)
        ht = jnp.asarray(rng.normal(0, 1, (t, b, h)), jnp.float32)
        h0 = jnp.asarray(rng.normal(0, 1, (b, h)), jnp.float32)
        fast = ref.mingru_scan_ref(z, ht, h0)
        # sequential loop
        slow = []
        hc = np.array(h0)
        for k in range(t):
            hc = np.array(z[k]) * np.array(ht[k]) + (1 - np.array(z[k])) * hc
            slow.append(hc.copy())
        np.testing.assert_allclose(np.array(fast), np.stack(slow),
                                   rtol=1e-4, atol=1e-5)
